//! # zest — Sublinear Partition Estimation
//!
//! A production-shaped reproduction of *"Sublinear Partition Estimation"*
//! (Rastogi & Van Durme, 2015). The library estimates the softmax
//! partition function
//!
//! ```text
//! Z(q) = Σ_{i=1..N} exp(v_i · q)
//! ```
//!
//! in **sublinear** time using three families of estimators built on top
//! of Maximum Inner Product Search (MIPS):
//!
//! * [`estimators::mimps::Mimps`] — MIPS-based importance sampling
//!   (paper eq. 5): exact head over the top-`k` set `S_k(q)` plus a
//!   uniform-tail correction from `l` samples.
//! * [`estimators::mince::Mince`] — MIPS-based noise-contrastive
//!   estimation (paper eq. 6/7): solve for `Z` as the single parameter
//!   of the head/noise discrimination objective with Newton or Halley
//!   steps.
//! * [`estimators::fmbe::Fmbe`] — Kar–Karnick random feature maps for
//!   the `exp` dot-product kernel (paper eq. 8–10) with precomputed
//!   `λ̃` sums.
//!
//! Substrates — the storage layer with epoch-snapshotted sharding
//! ([`store`]), the MIPS indexes ([`mips`], including the scatter-gather
//! [`mips::sharded::ShardedIndex`]), synthetic datasets matching the
//! paper's word2vec / Penn-Treebank workloads ([`data`]), an oracle
//! with controlled retrieval-error injection ([`oracle`]), a log-bilinear
//! language model trained with NCE ([`lm`]), a PJRT runtime that executes
//! AOT-compiled JAX/Pallas scoring graphs ([`runtime`]), a batching
//! service coordinator ([`coordinator`]), a network serving layer
//! ([`net`]: framed wire protocol, partition server/client, and
//! cross-process remote shards), and an observability layer ([`obs`]:
//! lock-free histograms, sampled request tracing, and scrapeable
//! telemetry) — are all implemented here; the crate has no
//! heavyweight dependencies.
//!
//! ## Quickstart
//!
//! ```no_run
//! use zest::data::synth::{SynthConfig, generate};
//! use zest::mips::brute::BruteIndex;
//! use zest::estimators::{EstimateContext, Estimator, mimps::Mimps};
//! use zest::util::rng::Rng;
//!
//! let store = generate(&SynthConfig { n: 10_000, d: 64, ..Default::default() });
//! let index = BruteIndex::new(&store);
//! let est = Mimps::new(1000, 1000);
//! let mut rng = Rng::seeded(0);
//! let q = store.row(42).to_vec();
//! let mut ctx = EstimateContext::new(&store, &index, &mut rng);
//! let zhat = est.estimate(&mut ctx, &q);
//! println!("Ẑ = {zhat}");
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimators;
pub mod experiments;
pub mod linalg;
pub mod lm;
pub mod loadgen;
pub mod metrics;
pub mod mips;
pub mod net;
pub mod obs;
pub mod oracle;
pub mod runtime;
pub mod store;
pub mod testing;
pub mod util;

pub use config::Config;
pub use data::embeddings::EmbeddingStore;
pub use estimators::Estimator;
pub use mips::MipsIndex;
pub use store::{ShardedStore, SnapshotHandle, StoreView};
