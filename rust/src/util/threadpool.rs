//! Data-parallel helpers over `std::thread::scope` (substitute for `rayon`).
//!
//! The hot loops in zest (brute-force scoring, table sweeps, index build)
//! are embarrassingly parallel over disjoint chunks; a scoped fork-join is
//! all we need — no work stealing, no global pool, no unsafe.

/// Number of worker threads to use: `ZEST_THREADS` or available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ZEST_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f(chunk_start, chunk)` over mutable disjoint chunks of `data` in
/// parallel. Chunks are `data.len() / threads` rounded up.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, slice) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * chunk, slice));
        }
    });
}

/// Apply `f(first_row, row_block)` over row-aligned mutable chunks of a
/// row-major (rows × row_len) matrix in parallel. Unlike
/// [`par_chunks_mut`], chunk boundaries never split a row — the batched
/// GEMM kernels rely on receiving whole rows.
pub fn par_row_chunks_mut<T: Send, F>(data: &mut [T], row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if row_len == 0 || data.is_empty() {
        return;
    }
    debug_assert_eq!(data.len() % row_len, 0);
    let rows = data.len() / row_len;
    let threads = threads.max(1).min(rows);
    let rows_per = rows.div_ceil(threads);
    let chunk = rows_per * row_len;
    std::thread::scope(|s| {
        for (ci, slice) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * rows_per, slice));
        }
    });
}

/// Parallel map over an index range, collecting results in order.
pub fn par_map<R: Send, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    par_chunks_mut(&mut out, threads, |start, slice| {
        for (j, slot) in slice.iter_mut().enumerate() {
            *slot = Some(f(start + j));
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Parallel fold: map each index to a partial value, then reduce partials
/// sequentially. `f` is applied in per-thread chunks to amortize overhead.
pub fn par_fold<A: Send, F, G>(n: usize, threads: usize, f: F, init: A, g: G) -> A
where
    F: Fn(std::ops::Range<usize>) -> A + Sync,
    G: Fn(A, A) -> A,
{
    if n == 0 {
        return init;
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    let partials: Vec<A> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let f = &f;
            handles.push(s.spawn(move || f(start..end)));
            start = end;
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    partials.into_iter().fold(init, g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_everything() {
        let mut v = vec![0usize; 1000];
        par_chunks_mut(&mut v, 7, |start, slice| {
            for (j, x) in slice.iter_mut().enumerate() {
                *x = start + j;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn par_row_chunks_mut_keeps_rows_whole() {
        let (rows, row_len) = (103, 7);
        let mut v = vec![0usize; rows * row_len];
        par_row_chunks_mut(&mut v, row_len, 5, |first_row, block| {
            assert_eq!(block.len() % row_len, 0, "chunk split a row");
            for (j, x) in block.iter_mut().enumerate() {
                *x = first_row * row_len + j;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 4, |i| i * i);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn par_fold_sums() {
        let total = par_fold(
            10_000,
            8,
            |r| r.map(|i| i as u64).sum::<u64>(),
            0u64,
            |a, b| a + b,
        );
        assert_eq!(total, 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn empty_input_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| {});
        assert_eq!(par_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(par_fold(0, 4, |_| 1, 7, |a, b| a + b), 7);
    }

    #[test]
    fn single_thread_path() {
        let out = par_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }
}
