//! Deterministic pseudo-randomness: xoshiro256** core plus the
//! distributions the paper's workloads need (uniform, normal, Zipf,
//! geometric, Rademacher, random unit vectors, reservoir/rejection
//! sampling without replacement).
//!
//! Everything is seedable and reproducible across runs — every
//! experimental table in the paper is reported over 3 seeds, and the
//! bench harness relies on bit-identical reruns.

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64, used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via splitmix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (uses both outputs? no — keeps it
    /// allocation-free and branch-simple; the second value is discarded,
    /// which costs one extra `sin` per pair but keeps state minimal).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rademacher: ±1 with equal probability.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Geometric distribution P[M = m] = (1-1/p) * (1/p)^m for m = 0,1,…
    /// parameterised as in Kar & Karnick (2012): P[M = m] = 1/p^{m+1},
    /// which is geometric with success probability 1 - 1/p (p > 1).
    pub fn geometric_kar(&mut self, p: f64) -> usize {
        debug_assert!(p > 1.0);
        let q = 1.0 / p; // failure probability
        let mut m = 0usize;
        while self.f64() < q && m < 64 {
            m += 1;
        }
        m
    }

    /// Random vector of iid standard normals.
    pub fn normal_vec(&mut self, d: usize) -> Vec<f32> {
        (0..d).map(|_| self.normal() as f32).collect()
    }

    /// Random unit vector (uniform on the sphere).
    pub fn unit_vec(&mut self, d: usize) -> Vec<f32> {
        let mut v = self.normal_vec(d);
        let norm = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
        let inv = (1.0 / norm.max(f64::MIN_POSITIVE)) as f32;
        for x in &mut v {
            *x *= inv;
        }
        v
    }

    /// Sample `m` distinct indices uniformly from `[0, n)` excluding any
    /// index for which `excluded` returns true. Uses rejection sampling
    /// (fine for m ≪ n, the regime the paper's tail sampling lives in) and
    /// falls back to a Fisher–Yates partial shuffle when m is a large
    /// fraction of the candidate pool.
    pub fn sample_distinct_excluding<F: Fn(usize) -> bool>(
        &mut self,
        n: usize,
        m: usize,
        excluded: F,
    ) -> Vec<usize> {
        let mut out = Vec::with_capacity(m);
        if m == 0 || n == 0 {
            return out;
        }
        // Estimate pool size cheaply: if m is a big fraction of n, do the
        // exact partial shuffle; otherwise rejection-sample.
        if m * 4 >= n {
            let mut pool: Vec<usize> = (0..n).filter(|&i| !excluded(i)).collect();
            let take = m.min(pool.len());
            for i in 0..take {
                let j = self.range(i, pool.len());
                pool.swap(i, j);
                out.push(pool[i]);
            }
            return out;
        }
        let mut seen = std::collections::HashSet::with_capacity(m * 2);
        let mut attempts = 0usize;
        let max_attempts = 100 * m + 1000;
        while out.len() < m && attempts < max_attempts {
            attempts += 1;
            let i = self.below(n);
            if excluded(i) || seen.contains(&i) {
                continue;
            }
            seen.insert(i);
            out.push(i);
        }
        if out.len() < m {
            // Pathological exclusion density — fall back to exact.
            let mut pool: Vec<usize> = (0..n)
                .filter(|&i| !excluded(i) && !seen.contains(&i))
                .collect();
            while out.len() < m && !pool.is_empty() {
                let j = self.below(pool.len());
                out.push(pool.swap_remove(j));
            }
        }
        out
    }

    /// Derive an independent child RNG (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

/// Zipf distribution over ranks `1..=n` with exponent `s`, using the
/// classic inverse-CDF-over-precomputed-table method. The paper's
/// workloads (word frequencies, corpus token draws) are Zipfian.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank (0 = most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of 0-based rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seeded(11);
        let mut counts = [0usize; 10];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expect = trials as f64 / 10.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn geometric_kar_matches_pmf() {
        // P[M=0] = 1/p with p=2 → 0.5.
        let mut r = Rng::seeded(9);
        let n = 100_000;
        let zeros = (0..n).filter(|_| r.geometric_kar(2.0) == 0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn unit_vec_has_unit_norm() {
        let mut r = Rng::seeded(13);
        let v = r.unit_vec(128);
        let norm: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn sample_distinct_excluding_respects_constraints() {
        let mut r = Rng::seeded(17);
        let excl: std::collections::HashSet<usize> = [0, 1, 2, 3].into_iter().collect();
        let s = r.sample_distinct_excluding(100, 20, |i| excl.contains(&i));
        assert_eq!(s.len(), 20);
        let uniq: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(uniq.len(), 20, "duplicates in sample");
        for i in &s {
            assert!(!excl.contains(i));
        }
    }

    #[test]
    fn sample_distinct_dense_exclusion_fallback() {
        let mut r = Rng::seeded(19);
        // Only 10 candidates remain; ask for all of them.
        let s = r.sample_distinct_excluding(100, 10, |i| i >= 10);
        assert_eq!(s.len(), 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::seeded(23);
        let mut head = 0usize;
        let n = 50_000;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Top-10 of a 1000-rank Zipf(1.1) carries a large share of mass.
        assert!(head as f64 / n as f64 > 0.4, "head mass {}", head as f64 / n as f64);
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(500, 1.0);
        let total: f64 = (0..500).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
