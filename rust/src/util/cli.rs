//! Minimal declarative command-line flag parser (substitute for `clap`,
//! which is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, typed accessors with defaults, and auto-generated help.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed argument set for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw argv fragments (everything after the subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates flag parsing.
                    positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: value or boolean flag?
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            flags.insert(body.to_string(), v);
                        }
                        _ => {
                            flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { flags, positional })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed accessor with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            Some(v) => v.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Typed accessor that errors when missing or malformed.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        let v = self
            .flags
            .get(key)
            .ok_or_else(|| format!("missing required flag --{key}"))?;
        v.parse()
            .map_err(|e| format!("bad value for --{key}: {e}"))
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of T.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Unknown-flag check against a declared set (catches typos early).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; known flags: {}",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

/// Help-text builder for subcommands.
pub struct HelpBuilder {
    name: String,
    about: String,
    entries: Vec<(String, String, String)>,
}

impl HelpBuilder {
    pub fn new(name: &str, about: &str) -> Self {
        HelpBuilder {
            name: name.to_string(),
            about: about.to_string(),
            entries: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &str, default: &str, about: &str) -> Self {
        self.entries
            .push((name.to_string(), default.to_string(), about.to_string()));
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "FLAGS:");
        for (n, d, a) in &self.entries {
            let _ = writeln!(s, "  --{n:<22} {a} [default: {d}]");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = args(&["--n", "100", "--d=32"]);
        assert_eq!(a.get_or("n", 0usize), 100);
        assert_eq!(a.get_or("d", 0usize), 32);
    }

    #[test]
    fn boolean_flags() {
        let a = args(&["--verbose", "--n", "5"]);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_or("n", 0usize), 5);
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = args(&["--n", "5", "--fast"]);
        assert!(a.get_bool("fast"));
    }

    #[test]
    fn positional_args() {
        let a = args(&["cmdarg", "--n", "5", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["cmdarg", "--not-a-flag"]);
    }

    #[test]
    fn list_flag() {
        let a = args(&["--ks", "1,10,100"]);
        assert_eq!(a.get_list::<usize>("ks", &[]), vec![1, 10, 100]);
    }

    #[test]
    fn require_missing_errors() {
        let a = args(&[]);
        assert!(a.require::<usize>("n").is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args(&["--oops", "1"]);
        assert!(a.check_known(&["n", "d"]).is_err());
        assert!(args(&["--n", "1"]).check_known(&["n"]).is_ok());
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.get_or("k", 1000usize), 1000);
        assert_eq!(a.get_list::<usize>("ls", &[10, 100]), vec![10, 100]);
    }
}
