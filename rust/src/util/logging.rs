//! Tiny leveled logger backing the `log` crate facade (substitute for
//! `env_logger`). Level comes from `ZEST_LOG` (error|warn|info|debug|trace,
//! matched case-insensitively), default `info`; an unrecognized value
//! warns once on stderr and falls back to `info`. Output goes to stderr
//! with elapsed-time stamps.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a `ZEST_LOG` level name, case-insensitively. `None` means
/// the value is not a recognized level.
pub(crate) fn parse_level(value: &str) -> Option<LevelFilter> {
    match value.trim().to_ascii_lowercase().as_str() {
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger once; safe to call repeatedly.
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("ZEST_LOG") {
            Ok(raw) => parse_level(&raw).unwrap_or_else(|| {
                eprintln!(
                    "[zest] unrecognized ZEST_LOG={raw:?} \
                     (expected error|warn|info|debug|trace); defaulting to info"
                );
                LevelFilter::Info
            }),
            Err(_) => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger {
            start: Instant::now(),
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    use log::LevelFilter;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }

    #[test]
    fn levels_parse_case_insensitively() {
        for (raw, want) in [
            ("error", LevelFilter::Error),
            ("ERROR", LevelFilter::Error),
            ("Warn", LevelFilter::Warn),
            ("INFO", LevelFilter::Info),
            ("info", LevelFilter::Info),
            ("DeBuG", LevelFilter::Debug),
            ("trace", LevelFilter::Trace),
            (" trace ", LevelFilter::Trace),
        ] {
            assert_eq!(super::parse_level(raw), Some(want), "raw={raw:?}");
        }
        for raw in ["", "verbose", "infoo", "3", "warn,debug"] {
            assert_eq!(super::parse_level(raw), None, "raw={raw:?}");
        }
    }
}
