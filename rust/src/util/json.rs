//! Minimal JSON value model, parser and writer (substitute for `serde_json`).
//!
//! Used for: experiment result files, `artifacts/meta.json` (written by the
//! python AOT step, read by the runtime), and run configs. Supports the
//! full JSON grammar minus exotic escapes (\u surrogate pairs are decoded).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("short \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", esc as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e1}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-25.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }
}
