//! Small, dependency-free substrates that stand in for crates the build
//! environment does not provide (rand, clap, serde, rayon, env_logger).

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;
