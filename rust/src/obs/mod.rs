//! Observability: lock-free histograms, per-request tracing, and
//! scrapeable telemetry export.
//!
//! Three legs, layered bottom-up:
//!
//! - [`hist`] — atomic log-linear [`Histogram`]s with mergeable
//!   [`HistogramSnapshot`]s: the storage behind every latency
//!   percentile the serving stack reports.
//! - [`trace`] — sampled per-request [`Trace`] spans (frontdoor →
//!   queue → batch → per-worker RPC) collected in a bounded
//!   [`TraceRing`] and dumpable as Chrome `trace_event` JSON.
//! - [`export`] — the [`MetricsBlob`] name→value form that crosses the
//!   wire (`GetMetrics`), merges across cluster nodes, and renders as
//!   Prometheus text via [`MetricsHttpServer`].
//!
//! See `docs/OBSERVABILITY.md` for the operator-facing tour.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{MetricsBlob, MetricsHttpServer};
pub use hist::{Histogram, HistogramSnapshot};
pub use trace::{CompletedTrace, SpanEvent, Trace, TraceRing, TraceSampler, COORD_TRACK};
