//! Scrapeable telemetry export: a generic metrics blob, Prometheus
//! text exposition, and a minimal HTTP endpoint serving it.
//!
//! [`MetricsBlob`] is the wire- and merge-friendly form of a metrics
//! snapshot: named counters plus named [`HistogramSnapshot`]s. Because
//! histograms merge exactly (bucket-wise addition), a coordinator can
//! fan `GetMetrics` out to its shard workers and fold every response
//! into one cluster-wide blob whose percentiles are as accurate as any
//! single node's.
//!
//! [`MetricsHttpServer`] binds a plain HTTP/1.0 listener (TCP or UDS)
//! and answers `GET /metrics` with [`MetricsBlob::to_prometheus_text`]
//! — enough for Prometheus, curl, or the CI smoke test, with no HTTP
//! dependency.

use crate::net::{Addr, Listener, Stream};
use crate::obs::hist::HistogramSnapshot;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// A named-counters + named-histograms snapshot, mergeable across
/// nodes and encodable on the wire.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsBlob {
    /// Monotonic counters and point-in-time gauges, by name.
    pub counters: Vec<(String, u64)>,
    /// Latency/size distributions, by name.
    pub hists: Vec<(String, HistogramSnapshot)>,
}

impl MetricsBlob {
    /// The counter named `name`, or 0 when absent.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, v)| *v)
    }

    /// The histogram named `name`, if present.
    pub fn hist(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Fold `other` into `self`: counters with the same name add,
    /// histograms with the same name merge exactly, unseen names
    /// append. Merging a worker's blob into the coordinator's yields
    /// cluster-wide totals and distributions.
    pub fn merge(&mut self, other: &MetricsBlob) {
        for (name, v) in &other.counters {
            match self.counters.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += v,
                None => self.counters.push((name.clone(), *v)),
            }
        }
        for (name, h) in &other.hists {
            match self.hists.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => mine.merge(h),
                None => self.hists.push((name.clone(), h.clone())),
            }
        }
    }

    /// Render in the Prometheus text exposition format. Counters
    /// become `zest_<name>` counter samples; histograms become
    /// summaries with p50/p99/p999 quantile samples plus `_sum` and
    /// `_count` (sums of nanosecond values are emitted as recorded).
    pub fn to_prometheus_text(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let full = format!("zest_{name}");
            out.push_str(&format!("# TYPE {full} counter\n{full} {v}\n"));
        }
        for (name, h) in &self.hists {
            let full = format!("zest_{name}");
            out.push_str(&format!("# TYPE {full} summary\n"));
            for (q, label) in [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")] {
                out.push_str(&format!(
                    "{full}{{quantile=\"{label}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("{full}_sum {}\n", h.sum));
            out.push_str(&format!("{full}_count {}\n", h.count));
        }
        out
    }
}

/// A background thread serving `GET /metrics` (Prometheus text) on a
/// [`crate::net::Addr`]. Dropping the server (or calling
/// [`MetricsHttpServer::shutdown`]) stops the thread.
pub struct MetricsHttpServer {
    addr: Addr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsHttpServer {
    /// Bind `addr` and serve `source()` as Prometheus text on every
    /// `GET /metrics` (or `GET /`). `tcp://host:0` resolves to an
    /// ephemeral port readable from [`MetricsHttpServer::addr`].
    pub fn serve(
        addr: &Addr,
        source: Arc<dyn Fn() -> MetricsBlob + Send + Sync>,
    ) -> std::io::Result<MetricsHttpServer> {
        let listener = Listener::bind(addr)?;
        let bound = listener.bound_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("zest-metrics-http".into())
            .spawn(move || {
                while !flag.load(Ordering::Acquire) {
                    let mut stream = match listener.accept() {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if flag.load(Ordering::Acquire) {
                        break;
                    }
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                    let _ = serve_one(&mut stream, &*source);
                }
            })?;
        Ok(MetricsHttpServer {
            addr: bound,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address (with `:0` resolved).
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(&mut self) {
        if let Some(thread) = self.thread.take() {
            self.stop.store(true, Ordering::Release);
            // Unblock the accept call with a throwaway connection.
            let _ = Stream::connect(&self.addr);
            let _ = thread.join();
        }
    }
}

impl Drop for MetricsHttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read one HTTP request head and answer it. Tolerates pipelined-free
/// HTTP/1.0 clients only (curl, Prometheus scrapers): read until the
/// blank line, answer, close.
fn serve_one(
    stream: &mut Stream,
    source: &(dyn Fn() -> MetricsBlob + Send + Sync),
) -> std::io::Result<()> {
    let mut head = Vec::with_capacity(512);
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut byte) {
            Ok(0) => break,
            Ok(_) => head.push(byte[0]),
            Err(e) => return Err(e),
        }
    }
    let request_line = std::str::from_utf8(&head)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, body) = if method == "GET" && (path == "/metrics" || path == "/") {
        ("200 OK", source().to_prometheus_text())
    } else {
        ("404 Not Found", String::from("not found\n"))
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Histogram;

    fn blob_with(counter: u64, samples: &[u64]) -> MetricsBlob {
        let h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        MetricsBlob {
            counters: vec![("completed".into(), counter)],
            hists: vec![("queue_ns".into(), h.snapshot())],
        }
    }

    #[test]
    fn merge_adds_counters_and_merges_hists() {
        let mut a = blob_with(3, &[100, 200]);
        let b = blob_with(4, &[300]);
        a.merge(&b);
        assert_eq!(a.counter("completed"), 7);
        assert_eq!(a.hist("queue_ns").unwrap().count, 3);
        // Unseen names append.
        let extra = MetricsBlob {
            counters: vec![("shed".into(), 2)],
            hists: vec![],
        };
        a.merge(&extra);
        assert_eq!(a.counter("shed"), 2);
        assert_eq!(a.counter("missing"), 0);
        assert!(a.hist("missing").is_none());
    }

    #[test]
    fn prometheus_text_exposes_counters_and_summaries() {
        let text = blob_with(5, &[1_000, 2_000, 4_000]).to_prometheus_text();
        assert!(text.contains("# TYPE zest_completed counter"));
        assert!(text.contains("zest_completed 5"));
        assert!(text.contains("# TYPE zest_queue_ns summary"));
        assert!(text.contains("zest_queue_ns{quantile=\"0.5\"}"));
        assert!(text.contains("zest_queue_ns{quantile=\"0.999\"}"));
        assert!(text.contains("zest_queue_ns_count 3"));
    }

    /// The serving-health counters a load generator and its dashboards
    /// key on — deadline sheds, backpressure rejects, failovers and
    /// hedges — render as well-formed Prometheus counter samples.
    #[test]
    fn prometheus_text_covers_shed_and_hedge_counters() {
        let blob = MetricsBlob {
            counters: vec![
                ("shed".into(), 4),
                ("deadline_shed".into(), 7),
                ("shard_failovers".into(), 2),
                ("shard_hedges".into(), 31),
            ],
            hists: vec![],
        };
        let text = blob.to_prometheus_text();
        for (name, v) in [
            ("zest_shed", 4u64),
            ("zest_deadline_shed", 7),
            ("zest_shard_failovers", 2),
            ("zest_shard_hedges", 31),
        ] {
            assert!(text.contains(&format!("# TYPE {name} counter\n")), "{text}");
            assert!(text.contains(&format!("\n{name} {v}\n")), "{text}");
        }
    }

    #[test]
    fn http_endpoint_serves_metrics_and_404s_elsewhere() {
        let source: Arc<dyn Fn() -> MetricsBlob + Send + Sync> =
            Arc::new(|| blob_with(9, &[5_000]));
        let mut server =
            MetricsHttpServer::serve(&Addr::parse("tcp://127.0.0.1:0").unwrap(), source)
                .expect("bind ephemeral metrics port");
        let addr = server.addr().clone();

        let fetch = |path: &str| {
            let mut s = Stream::connect(&addr).unwrap();
            s.write_all(format!("GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes())
                .unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let ok = fetch("/metrics");
        assert!(ok.starts_with("HTTP/1.0 200"), "got: {ok}");
        assert!(ok.contains("zest_completed 9"));
        assert!(fetch("/nope").starts_with("HTTP/1.0 404"));
        server.shutdown();
    }
}
