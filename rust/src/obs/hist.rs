//! Lock-free log-linear latency histograms.
//!
//! [`Histogram`] is the recording side: a fixed array of atomic
//! counters a hot path can feed with one `fetch_add`, no locks and no
//! allocation — the replacement for the old bounded
//! `Mutex<Vec<u64>>` reservoirs in [`crate::coordinator::ServiceMetrics`],
//! which silently dropped every sample past the first 65,536 and froze
//! percentiles on startup traffic.
//!
//! ## Bucket scheme
//!
//! Values (nanoseconds) are bucketed **log-linearly**: each power-of-2
//! range `[2^h, 2^(h+1))` splits into `2^SUB_BITS = 32` equal linear
//! sub-buckets, and values below 32 get one exact bucket each. A
//! bucket's width is therefore at most `1/32` of its lower bound, so
//! any quantile read from bucket upper bounds is within **+3.125%**
//! relative error of the true sample — uniform across the full `u64`
//! range, with no saturation and no bias toward early samples.
//!
//! Bucket counts are plain `AtomicU64`s, which makes histograms
//! **mergeable by addition**: [`HistogramSnapshot::merge`] sums two
//! snapshots bucket-by-bucket, exactly — the property
//! `RemoteCluster::cluster_metrics` uses to combine per-worker
//! latency distributions into one cluster-wide view.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Sub-bucket resolution: each power-of-2 range splits into
/// `2^SUB_BITS` linear sub-buckets (32 → ≤ 3.125% relative error).
pub const SUB_BITS: u32 = 5;

const SUB_COUNT: usize = 1 << SUB_BITS;
const SUB_MASK: u64 = (SUB_COUNT as u64) - 1;

/// Total bucket count: one exact bucket per value below `2^SUB_BITS`,
/// then 32 sub-buckets per power-of-2 range up to `2^64`.
pub const NUM_BUCKETS: usize = (64 - SUB_BITS as usize) * SUB_COUNT;

/// Map a value to its bucket index (0-based, `< NUM_BUCKETS`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB_COUNT as u64 {
        return v as usize;
    }
    let h = 63 - v.leading_zeros(); // highest set bit; h >= SUB_BITS
    let shift = h - SUB_BITS;
    let base = ((h - SUB_BITS + 1) as usize) << SUB_BITS;
    base + ((v >> shift) & SUB_MASK) as usize
}

/// Inclusive `[lower, upper]` value range of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    debug_assert!(i < NUM_BUCKETS);
    if i < SUB_COUNT {
        return (i as u64, i as u64);
    }
    let h = (i >> SUB_BITS) as u32 + SUB_BITS - 1;
    let sub = (i as u64) & SUB_MASK;
    let width = 1u64 << (h - SUB_BITS);
    let lo = (SUB_COUNT as u64 + sub) << (h - SUB_BITS);
    (lo, lo.saturating_add(width - 1))
}

/// A lock-free log-linear histogram of `u64` samples (nanoseconds by
/// convention). Recording is one relaxed `fetch_add` per counter —
/// cheap enough for the request hot path — and never saturates:
/// every sample lands, no matter how many came before it.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram (allocates the full fixed bucket array:
    /// `NUM_BUCKETS` × 8 bytes ≈ 15 KiB).
    pub fn new() -> Histogram {
        let buckets = (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record one duration as nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// A point-in-time sparse copy of the counters (the mergeable /
    /// wire-shippable form; quantiles are computed on it).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                buckets.push((i as u32, c));
            }
        }
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }

    /// Convenience quantile straight off the live counters (snapshots
    /// internally; prefer [`Histogram::snapshot`] when reading several).
    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }
}

/// A point-in-time, sparse, mergeable copy of a [`Histogram`]:
/// `(bucket index, count)` pairs in ascending index order plus the
/// count/sum/max scalars. This is the form that travels on the wire
/// (`Response::Metrics`) and that [`merge`](HistogramSnapshot::merge)
/// combines across workers.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Sum of all samples (mean = `sum / count`).
    pub sum: u64,
    /// Largest sample recorded.
    pub max: u64,
    /// Sparse `(bucket index, count)` pairs, ascending by index.
    pub buckets: Vec<(u32, u64)>,
}

impl HistogramSnapshot {
    /// The `q`-quantile (`0.0 ..= 1.0`) as the **upper bound** of the
    /// bucket holding the target sample — at most `1/2^SUB_BITS`
    /// (3.125%) above the true sample value, never below it. Returns 0
    /// on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let total: u64 = self.buckets.iter().map(|&(_, c)| c).sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for &(i, c) in &self.buckets {
            cum += c;
            if cum >= target {
                return bucket_bounds(i as usize).1;
            }
        }
        bucket_bounds(self.buckets.last().map(|&(i, _)| i as usize).unwrap_or(0)).1
    }

    /// [`quantile`](HistogramSnapshot::quantile) as a [`Duration`]
    /// (samples are nanoseconds by convention).
    pub fn quantile_duration(&self, q: f64) -> Duration {
        Duration::from_nanos(self.quantile(q))
    }

    /// Median.
    pub fn p50(&self) -> Duration {
        self.quantile_duration(0.50)
    }

    /// 99th percentile.
    pub fn p99(&self) -> Duration {
        self.quantile_duration(0.99)
    }

    /// 99.9th percentile.
    pub fn p999(&self) -> Duration {
        self.quantile_duration(0.999)
    }

    /// Mean sample value (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Fold `other` into `self` by bucket-wise addition. Merging is
    /// exact (no re-sampling error) and associative/commutative, so a
    /// cluster-wide distribution can be assembled from per-worker
    /// snapshots in any order.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
        let mut merged = Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut a, mut b) = (self.buckets.iter().peekable(), other.buckets.iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (Some(&&(ia, ca)), Some(&&(ib, cb))) => {
                    if ia == ib {
                        merged.push((ia, ca + cb));
                        a.next();
                        b.next();
                    } else if ia < ib {
                        merged.push((ia, ca));
                        a.next();
                    } else {
                        merged.push((ib, cb));
                        b.next();
                    }
                }
                (Some(&&x), None) => {
                    merged.push(x);
                    a.next();
                }
                (None, Some(&&x)) => {
                    merged.push(x);
                    b.next();
                }
                (None, None) => break,
            }
        }
        self.buckets = merged;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_bounds_cover_and_stay_tight() {
        let mut probes: Vec<u64> = (0..200u64).collect();
        for h in SUB_BITS..63 {
            let p = 1u64 << h;
            probes.extend_from_slice(&[p - 1, p, p + 1, p + (p >> 1), (p << 1) - 1]);
        }
        probes.push(u64::MAX);
        let mut rng = Rng::seeded(7);
        for _ in 0..10_000 {
            probes.push(rng.next_u64());
        }
        for &v in &probes {
            let i = bucket_index(v);
            assert!(i < NUM_BUCKETS, "index {i} out of range for {v}");
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "{v} outside bucket [{lo}, {hi}]");
            if v >= SUB_COUNT as u64 {
                // Relative width bound: the quantile error guarantee.
                assert!(
                    (hi - lo) as f64 <= lo as f64 / SUB_COUNT as f64,
                    "bucket [{lo}, {hi}] wider than lo/{SUB_COUNT}"
                );
            } else {
                assert_eq!(lo, hi, "linear region buckets are exact");
            }
        }
        // Buckets tile the line: consecutive indices abut exactly.
        for i in 0..NUM_BUCKETS - 1 {
            let (_, hi) = bucket_bounds(i);
            let (lo_next, _) = bucket_bounds(i + 1);
            assert_eq!(hi.wrapping_add(1), lo_next, "gap after bucket {i}");
        }
    }

    /// Quantiles against an exact sorted-Vec oracle, on three sample
    /// shapes: uniform, lognormal-ish (exp of a sum of uniforms), and
    /// an adversarial pile-up exactly on bucket edges.
    #[test]
    fn quantiles_match_oracle_within_bucket_error() {
        let mut rng = Rng::seeded(42);
        let uniform: Vec<u64> = (0..100_000)
            .map(|_| rng.below(50_000_000) as u64)
            .collect();
        let lognormal: Vec<u64> = (0..100_000)
            .map(|_| (1e4 * (0.8 * rng.normal()).exp()) as u64)
            .collect();
        let edges: Vec<u64> = (0..50_000)
            .map(|_| {
                let h = SUB_BITS + rng.below(20) as u32;
                let p = 1u64 << h;
                // Exactly on and around power-of-2 / sub-bucket edges.
                match rng.below(4) {
                    0 => p,
                    1 => p - 1,
                    2 => p + (p >> SUB_BITS),
                    _ => p + (p >> SUB_BITS) - 1,
                }
            })
            .collect();
        for samples in [&uniform, &lognormal, &edges] {
            let h = Histogram::new();
            for &v in samples.iter() {
                h.record(v);
            }
            let mut sorted = samples.to_vec();
            sorted.sort_unstable();
            let snap = h.snapshot();
            assert_eq!(snap.count, samples.len() as u64);
            for q in [0.5, 0.9, 0.99, 0.999] {
                let exact = sorted[((q * sorted.len() as f64).ceil() as usize).max(1) - 1];
                let got = snap.quantile(q);
                assert!(got >= exact, "q{q}: {got} < exact {exact}");
                let bound = exact + exact / (SUB_COUNT as u64 / 2) + 1;
                assert!(got <= bound, "q{q}: {got} > bound {bound} (exact {exact})");
            }
        }
    }

    #[test]
    fn merge_is_associative_and_exact() {
        let mut rng = Rng::seeded(3);
        let mk = |rng: &mut Rng, scale: usize| {
            let h = Histogram::new();
            for _ in 0..10_000 {
                h.record(rng.below(scale) as u64);
            }
            h.snapshot()
        };
        let a = mk(&mut rng, 1_000);
        let b = mk(&mut rng, 1_000_000);
        let c = mk(&mut rng, 1_000_000_000);
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must be associative");
        assert_eq!(ab_c.count, 30_000);
        assert_eq!(
            ab_c.buckets.iter().map(|&(_, c)| c).sum::<u64>(),
            30_000,
            "no sample lost or duplicated by merging"
        );
        // Merging with an empty snapshot is the identity.
        let mut with_empty = ab_c.clone();
        with_empty.merge(&HistogramSnapshot::default());
        assert_eq!(with_empty, ab_c);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        use std::sync::Arc;
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 100_000;
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        h.record((t as u64 + 1) * 1_000 + (i % 997));
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let snap = h.snapshot();
        let want = (THREADS as u64) * PER_THREAD;
        assert_eq!(snap.count, want, "total count must be exact");
        assert_eq!(
            snap.buckets.iter().map(|&(_, c)| c).sum::<u64>(),
            want,
            "bucket counts must sum to the total"
        );
        assert!(snap.max >= 8_000 && snap.quantile(1.0) >= 8_000);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), Duration::ZERO);
    }
}
