//! Per-request tracing: sampled spans, a bounded completed-trace ring,
//! and Chrome `trace_event` JSON export.
//!
//! A [`Trace`] is a cheap clonable handle (an `Arc`) attached to a
//! sampled `EstimateSpec` at the front door and carried through the
//! queue, the batcher, the backend and — for cluster backends — each
//! per-worker scatter RPC. Every stage appends a [`SpanEvent`] with
//! monotonic timestamps relative to the trace's origin:
//!
//! ```text
//! track 0 (coordinator): frontdoor ─ queue ─ batch
//! track 1+s (shard s):              rpc [worker_handle_ns/worker_exec_ns]
//! ```
//!
//! Completed traces land in the service's bounded [`TraceRing`], which
//! dumps as a Chrome `trace_event` JSON array
//! ([`TraceRing::to_chrome_json`]) loadable in `chrome://tracing` /
//! Perfetto: one "process" per trace (pid = trace id), one "thread"
//! per track (tid 0 = coordinator, tid 1+s = shard s).
//!
//! Sampling ([`TraceSampler`]) is deterministic every-Nth rather than
//! random so overhead is predictable and tests are reproducible; rate
//! `0.0` disables tracing entirely and costs one relaxed atomic
//! increment per request.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Track id of coordinator-side spans (Chrome `tid` 0); shard `s`
/// records on track `1 + s`.
pub const COORD_TRACK: u64 = 0;

/// One recorded span: a named interval on a track, with optional
/// string arguments (shown in the Chrome trace viewer's detail pane).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Stage name (`frontdoor`, `queue`, `batch`, `rpc`, ...).
    pub name: String,
    /// Start offset from the trace origin, nanoseconds.
    pub start_ns: u64,
    /// Span duration, nanoseconds.
    pub dur_ns: u64,
    /// Track: [`COORD_TRACK`] or `1 + shard` for per-worker spans.
    pub track: u64,
    /// Extra key/value detail (admit outcome, worker-side timings...).
    pub args: Vec<(String, String)>,
}

#[derive(Debug)]
struct TraceInner {
    id: u64,
    origin: Instant,
    events: Mutex<Vec<SpanEvent>>,
}

/// A live per-request trace handle. Clones share one event list; the
/// handle crosses threads with the request (queue → batcher → worker →
/// cluster scatter). Only sampled requests carry one, so the interior
/// mutex is off the common path entirely.
#[derive(Clone, Debug)]
pub struct Trace {
    inner: Arc<TraceInner>,
}

impl Trace {
    /// Start a trace now; `id` becomes the Chrome `pid`.
    pub fn start(id: u64) -> Trace {
        Trace {
            inner: Arc::new(TraceInner {
                id,
                origin: Instant::now(),
                events: Mutex::new(Vec::new()),
            }),
        }
    }

    /// The trace id.
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// The monotonic instant all span offsets are relative to.
    pub fn origin(&self) -> Instant {
        self.inner.origin
    }

    /// Nanoseconds elapsed since the origin.
    pub fn elapsed_ns(&self) -> u64 {
        self.inner.origin.elapsed().as_nanos() as u64
    }

    /// Append a fully specified span.
    pub fn add(&self, ev: SpanEvent) {
        self.inner.events.lock().unwrap().push(ev);
    }

    /// Append a span that started at `start` (an instant at or after
    /// the origin) and lasted `dur`, on `track`, with `args`.
    pub fn span_at(
        &self,
        name: &str,
        start: Instant,
        dur: Duration,
        track: u64,
        args: Vec<(String, String)>,
    ) {
        let start_ns = start
            .checked_duration_since(self.inner.origin)
            .unwrap_or(Duration::ZERO)
            .as_nanos() as u64;
        self.add(SpanEvent {
            name: name.to_string(),
            start_ns,
            dur_ns: dur.as_nanos() as u64,
            track,
            args,
        });
    }

    /// Append a coordinator-track span running from `start` to now.
    pub fn span_since(&self, name: &str, start: Instant) {
        self.span_at(name, start, start.elapsed(), COORD_TRACK, Vec::new());
    }

    /// A copy of the events recorded so far.
    pub fn events(&self) -> Vec<SpanEvent> {
        self.inner.events.lock().unwrap().clone()
    }

    /// Seal the trace into its completed, ring-storable form. Events
    /// are sorted by start offset so dumps read chronologically even
    /// when worker spans raced in out of order.
    pub fn finish(&self) -> CompletedTrace {
        let mut events = self.events();
        events.sort_by_key(|e| (e.start_ns, e.track));
        CompletedTrace {
            id: self.inner.id,
            wall_ns: self.elapsed_ns(),
            events,
        }
    }
}

/// A finished trace: id, end-to-end wall time, and its spans in start
/// order.
#[derive(Clone, Debug)]
pub struct CompletedTrace {
    /// Trace id (Chrome `pid`).
    pub id: u64,
    /// Origin-to-finish wall time, nanoseconds.
    pub wall_ns: u64,
    /// Spans in ascending start order.
    pub events: Vec<SpanEvent>,
}

impl CompletedTrace {
    /// The total duration recorded under spans named `name`.
    pub fn stage_ns(&self, name: &str) -> u64 {
        self.events
            .iter()
            .filter(|e| e.name == name)
            .map(|e| e.dur_ns)
            .sum()
    }
}

/// Bounded ring of completed traces: pushes past the capacity evict
/// the oldest, so the ring always holds the most recent window.
pub struct TraceRing {
    cap: usize,
    ring: Mutex<VecDeque<CompletedTrace>>,
}

impl TraceRing {
    /// A ring holding at most `cap` completed traces (`cap == 0`
    /// accepts nothing).
    pub fn new(cap: usize) -> TraceRing {
        TraceRing {
            cap,
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Store one completed trace, evicting the oldest when full.
    pub fn push(&self, t: CompletedTrace) {
        if self.cap == 0 {
            return;
        }
        let mut g = self.ring.lock().unwrap();
        if g.len() == self.cap {
            g.pop_front();
        }
        g.push_back(t);
    }

    /// Completed traces currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Whether the ring holds no traces.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A copy of the held traces, oldest first.
    pub fn completed(&self) -> Vec<CompletedTrace> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Dump every held trace as a Chrome `trace_event` JSON array of
    /// complete (`"ph": "X"`) events — loadable directly in
    /// `chrome://tracing` or Perfetto. `ts`/`dur` are microseconds
    /// (fractional, preserving nanosecond resolution); `pid` is the
    /// trace id and `tid` the track.
    pub fn to_chrome_json(&self) -> String {
        let mut events = Vec::new();
        for t in self.ring.lock().unwrap().iter() {
            for e in &t.events {
                let mut obj = vec![
                    ("name", Json::str(&e.name)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.start_ns as f64 / 1e3)),
                    ("dur", Json::num(e.dur_ns as f64 / 1e3)),
                    ("pid", Json::num(t.id as f64)),
                    ("tid", Json::num(e.track as f64)),
                ];
                if !e.args.is_empty() {
                    obj.push((
                        "args",
                        Json::obj(
                            e.args
                                .iter()
                                .map(|(k, v)| (k.as_str(), Json::str(v)))
                                .collect(),
                        ),
                    ));
                }
                events.push(Json::obj(obj));
            }
        }
        Json::Arr(events).to_string()
    }
}

/// Deterministic every-Nth request sampler handing out fresh traces.
pub struct TraceSampler {
    /// Sample every `period`-th request; 0 = tracing off.
    period: u64,
    tick: AtomicU64,
    next_id: AtomicU64,
}

impl TraceSampler {
    /// A sampler firing on roughly `rate` of requests (`1.0` = every
    /// request, `0.01` = every 100th, `<= 0.0` = never). The rate is
    /// rounded to the nearest every-Nth period.
    pub fn new(rate: f64) -> TraceSampler {
        let period = if rate <= 0.0 {
            0
        } else {
            (1.0 / rate.min(1.0)).round().max(1.0) as u64
        };
        TraceSampler {
            period,
            tick: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
        }
    }

    /// Whether any request can ever be sampled.
    pub fn enabled(&self) -> bool {
        self.period > 0
    }

    /// Hand out a fresh [`Trace`] if this request is sampled. One
    /// relaxed atomic increment when tracing is on; a plain load when
    /// off.
    pub fn sample(&self) -> Option<Trace> {
        if self.period == 0 {
            return None;
        }
        if self.tick.fetch_add(1, Ordering::Relaxed) % self.period != 0 {
            return None;
        }
        Some(Trace::start(self.next_id.fetch_add(1, Ordering::Relaxed) + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_and_finish_sorted() {
        let t = Trace::start(9);
        let origin = t.origin();
        t.span_at(
            "rpc",
            origin + Duration::from_micros(50),
            Duration::from_micros(20),
            2,
            vec![("shard".into(), "1".into())],
        );
        t.span_at("queue", origin, Duration::from_micros(40), COORD_TRACK, vec![]);
        let done = t.finish();
        assert_eq!(done.id, 9);
        assert_eq!(done.events.len(), 2);
        assert_eq!(done.events[0].name, "queue", "sorted by start offset");
        assert_eq!(done.events[1].track, 2);
        assert_eq!(done.stage_ns("rpc"), 20_000);
    }

    #[test]
    fn ring_is_bounded_and_evicts_oldest() {
        let ring = TraceRing::new(2);
        for id in 1..=3 {
            ring.push(Trace::start(id).finish());
        }
        let held = ring.completed();
        assert_eq!(held.len(), 2);
        assert_eq!(held[0].id, 2);
        assert_eq!(held[1].id, 3);
        assert!(TraceRing::new(0).is_empty());
    }

    #[test]
    fn chrome_dump_is_valid_json_with_complete_events() {
        let ring = TraceRing::new(8);
        let t = Trace::start(1);
        t.span_at(
            "batch",
            t.origin(),
            Duration::from_micros(5),
            COORD_TRACK,
            vec![("group".into(), "k=5,l=5".into())],
        );
        ring.push(t.finish());
        let dump = ring.to_chrome_json();
        let parsed = Json::parse(&dump).expect("chrome dump must be valid JSON");
        let events = parsed.as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("name").unwrap().as_str(), Some("batch"));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(5.0));
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            e.get("args").unwrap().get("group").unwrap().as_str(),
            Some("k=5,l=5")
        );
    }

    #[test]
    fn sampler_rates_fire_every_nth() {
        let off = TraceSampler::new(0.0);
        assert!(!off.enabled());
        assert!((0..100).all(|_| off.sample().is_none()));
        let all = TraceSampler::new(1.0);
        assert!((0..100).all(|_| all.sample().is_some()));
        let one_pct = TraceSampler::new(0.01);
        let fired = (0..1000).filter(|_| one_pct.sample().is_some()).count();
        assert_eq!(fired, 10, "1% sampling fires exactly every 100th");
        // Ids are distinct and start at 1.
        let s = TraceSampler::new(1.0);
        let a = s.sample().unwrap();
        let b = s.sample().unwrap();
        assert_eq!((a.id(), b.id()), (1, 2));
    }
}
