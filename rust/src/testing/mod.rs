//! Property-testing helpers (substitute for proptest) and wire-level
//! fault injection for chaos tests.
pub mod fault;
pub mod prop;
