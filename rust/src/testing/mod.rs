//! Property-testing helpers (substitute for proptest).
pub mod prop;
