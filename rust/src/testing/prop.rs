//! Lightweight property-testing helpers (substitute for `proptest`):
//! seeded case generation with automatic shrinking of failing sizes.
//!
//! Usage:
//! ```ignore
//! prop::check(100, |rng| {
//!     let n = rng.range(1, 500);
//!     /* build inputs from rng, assert the invariant, return Ok(()) or Err(msg) */
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Run `cases` random trials of `property`. On failure, re-run with the
/// failing seed recorded in the panic message so the case is reproducible.
pub fn check<F>(cases: usize, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_seeded(0xC0FFEE, cases, property)
}

/// Like [`check`] with an explicit base seed.
pub fn check_seeded<F>(base_seed: u64, cases: usize, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats agree to a relative tolerance, with context.
pub fn assert_close(got: f64, want: f64, rel_tol: f64, what: &str) -> Result<(), String> {
    let denom = 1.0f64.max(want.abs());
    if ((got - want) / denom).abs() > rel_tol {
        return Err(format!("{what}: got {got}, want {want} (rel tol {rel_tol})"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(50, |rng| {
            let a = rng.f64();
            if (0.0..1.0).contains(&a) {
                Ok(())
            } else {
                Err(format!("{a} outside unit interval"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            let n = rng.below(4);
            if n < 3 {
                Ok(())
            } else {
                Err("hit 3".to_string())
            }
        });
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(assert_close(1.0, 1.01, 1e-3, "x").is_err());
        // Relative to max(1, |want|): large values scale.
        assert!(assert_close(1000.5, 1000.0, 1e-3, "x").is_ok());
    }
}
