//! Deterministic fault injection for the wire layer: a frame-aware
//! TCP/UDS proxy that sits between a client (`MuxSlot`, `PartitionClient`)
//! and a server, forwarding ZNW1 frames while injecting configured
//! faults — drop a frame, delay it, truncate it mid-frame, kill the
//! connection after N bytes, or refuse connections outright.
//!
//! The proxy is *frame-aware*: it parses each frame's 19-byte header
//! (`wire::decode_header`) so faults land on protocol-meaningful
//! boundaries ("drop the next response frame", "cut 7 bytes into a
//! frame") instead of arbitrary byte positions in a kernel buffer.
//! Determinism comes from the fault **schedule** being explicit — a
//! fixed [`FaultMode`] per connection, or a seeded [`FaultSchedule`]
//! mapping connection order to modes — not from byte-level timing,
//! which no socket proxy can pin.
//!
//! Used by `tests/chaos.rs` to prove the replica-failover invariant
//! (kill one replica mid-load ⇒ zero failed requests, bit-identical
//! answers) and reusable by any net test that wants a misbehaving peer.

use crate::net::wire::{self, HEADER_LEN};
use crate::net::{Addr, Listener, Stream};
use crate::util::rng::Rng;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What the proxy does with traffic on a connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// Forward every frame untouched (the healthy baseline).
    Forward,
    /// Forward every frame after sleeping this many milliseconds
    /// (injected latency; triggers client timeouts when larger than
    /// the client's deadline).
    Delay(u64),
    /// Swallow the next `n` frames whole (header + payload consumed,
    /// nothing forwarded), then forward normally. The peer waiting on
    /// a swallowed response observes a hang until its timeout.
    DropFrames(u32),
    /// Forward at most this many more bytes (per direction), cutting
    /// the connection mid-frame when the budget runs out inside one —
    /// the "truncate mid-frame" and "kill after N bytes" faults in one
    /// knob. A budget below [`HEADER_LEN`] kills on the first frame.
    CutAfter(usize),
    /// Sever any new connection immediately after accept (a down
    /// backend: connects succeed at the listener queue but die before
    /// a byte flows). Live connections are unaffected — pair with
    /// [`FaultProxy::cut_all`] to take a backend fully down.
    Refuse,
}

/// A seeded, reproducible assignment of [`FaultMode`]s to connection
/// order: connection `i` through the proxy runs under `mode(i)`. The
/// same seed always yields the same schedule, making a chaos run
/// replayable from its seed alone.
pub struct FaultSchedule {
    modes: Vec<FaultMode>,
}

impl FaultSchedule {
    /// Derive `len` modes from `seed`. The palette sticks to faults a
    /// correct stack must absorb (delays, dropped frames, mid-frame
    /// cuts) plus healthy connections; `Refuse` is excluded — taking a
    /// backend down wholesale is an explicit test action, not schedule
    /// noise.
    pub fn seeded(seed: u64, len: usize) -> FaultSchedule {
        let mut rng = Rng::seeded(seed ^ 0xFA_0175);
        let modes = (0..len)
            .map(|_| match rng.below(4) {
                0 | 1 => FaultMode::Forward,
                2 => FaultMode::Delay(1 + rng.below(3) as u64),
                _ => FaultMode::CutAfter(HEADER_LEN + rng.below(96)),
            })
            .collect();
        FaultSchedule { modes }
    }

    /// The mode for the `conn`-th accepted connection (wraps around
    /// past `len`).
    pub fn mode(&self, conn: usize) -> FaultMode {
        self.modes[conn % self.modes.len()]
    }
}

/// A fault-injecting proxy in front of one upstream server. Every
/// accepted connection gets a paired upstream connection and two pump
/// threads (one per direction) that forward whole frames, consulting
/// the connection's [`FaultMode`] before each.
///
/// Modes come from two places: the proxy-wide mode
/// ([`FaultProxy::set_mode`]), shared **live** with every connection
/// that wasn't given a schedule slot — flipping it mid-connection
/// changes behavior of in-flight pumps — or a per-connection slot from
/// an installed [`FaultSchedule`], which pins that connection's
/// behavior for its lifetime.
pub struct FaultProxy {
    addr: Addr,
    global: Arc<Mutex<FaultMode>>,
    schedule: Arc<Mutex<Option<FaultSchedule>>>,
    conns: Arc<Mutex<Vec<Stream>>>,
    accepted: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Bind `listen`, proxying every connection to `upstream`. Starts
    /// in [`FaultMode::Forward`].
    pub fn start(listen: &Addr, upstream: Addr) -> std::io::Result<FaultProxy> {
        let listener = Listener::bind(listen)?;
        let addr = listener.bound_addr()?;
        let global = Arc::new(Mutex::new(FaultMode::Forward));
        let schedule: Arc<Mutex<Option<FaultSchedule>>> = Arc::new(Mutex::new(None));
        let conns: Arc<Mutex<Vec<Stream>>> = Arc::new(Mutex::new(Vec::new()));
        let accepted = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let (global, schedule, conns, accepted, stop) = (
                global.clone(),
                schedule.clone(),
                conns.clone(),
                accepted.clone(),
                stop.clone(),
            );
            std::thread::Builder::new()
                .name("fault-proxy-accept".to_string())
                .spawn(move || {
                    accept_loop(listener, upstream, global, schedule, conns, accepted, stop)
                })
                .expect("spawn fault-proxy accept thread")
        };
        Ok(FaultProxy {
            addr,
            global,
            schedule,
            conns,
            accepted,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The address clients should connect to (resolves `:0` TCP ports).
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// Set the proxy-wide mode. Applies immediately to new connections
    /// and to live ones running without a schedule slot.
    pub fn set_mode(&self, mode: FaultMode) {
        *self.global.lock().unwrap() = mode;
    }

    /// Install (or clear) a per-connection schedule; scheduled slots
    /// override the proxy-wide mode for connections accepted from now
    /// on.
    pub fn set_schedule(&self, schedule: Option<FaultSchedule>) {
        *self.schedule.lock().unwrap() = schedule;
    }

    /// Connections accepted so far (schedule positions consumed).
    pub fn accepted(&self) -> usize {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Sever every live proxied connection right now (both directions
    /// of both legs) — the "kill the backend mid-load" action. New
    /// connections still proxy under the current mode; combine with
    /// [`FaultMode::Refuse`] to keep the backend down.
    pub fn cut_all(&self) {
        let mut conns = self.conns.lock().unwrap();
        for s in conns.drain(..) {
            sever(&s);
        }
    }

    /// Back to transparent forwarding: clears the schedule and resets
    /// the mode (already-cut connections stay cut; clients reconnect).
    pub fn restore(&self) {
        self.set_schedule(None);
        self.set_mode(FaultMode::Forward);
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = Stream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.cut_all();
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: Listener,
    upstream: Addr,
    global: Arc<Mutex<FaultMode>>,
    schedule: Arc<Mutex<Option<FaultSchedule>>>,
    conns: Arc<Mutex<Vec<Stream>>>,
    accepted: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
) {
    loop {
        let client = match listener.accept() {
            Ok(s) => s,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let conn_idx = accepted.fetch_add(1, Ordering::Relaxed);
        // A schedule slot pins this connection's mode for life; without
        // one the connection shares the live proxy-wide mode.
        let mode: Arc<Mutex<FaultMode>> = match schedule.lock().unwrap().as_ref() {
            Some(sched) => Arc::new(Mutex::new(sched.mode(conn_idx))),
            None => global.clone(),
        };
        if *mode.lock().unwrap() == FaultMode::Refuse {
            sever(&client);
            continue;
        }
        let server = match Stream::connect(&upstream) {
            Ok(s) => s,
            Err(_) => {
                sever(&client);
                continue;
            }
        };
        let (Ok(client_r), Ok(server_r)) = (client.try_clone(), server.try_clone()) else {
            sever(&client);
            sever(&server);
            continue;
        };
        {
            let mut live = conns.lock().unwrap();
            if let (Ok(c), Ok(s)) = (client.try_clone(), server.try_clone()) {
                live.push(c);
                live.push(s);
            }
        }
        let m1 = mode.clone();
        let m2 = mode;
        spawn_pump("fault-proxy-c2s", client_r, server, m1);
        spawn_pump("fault-proxy-s2c", server_r, client, m2);
    }
}

fn spawn_pump(name: &str, from: Stream, to: Stream, mode: Arc<Mutex<FaultMode>>) {
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || pump(from, to, mode))
        .expect("spawn fault-proxy pump thread");
}

/// Forward whole frames from `from` to `to`, consulting `mode` before
/// each. Exits (severing both streams) on any read/write failure, a
/// malformed header, or an exhausted `CutAfter` budget.
fn pump(mut from: Stream, mut to: Stream, mode: Arc<Mutex<FaultMode>>) {
    // Bytes this direction has forwarded, charged against `CutAfter`.
    let mut forwarded = 0usize;
    loop {
        let mut header = [0u8; HEADER_LEN];
        if from.read_exact(&mut header).is_err() {
            break;
        }
        let Ok((_, _, payload_len)) = wire::decode_header(&header) else {
            break;
        };
        let mut payload = vec![0u8; payload_len];
        if from.read_exact(&mut payload).is_err() {
            break;
        }
        // Decide under the lock, act outside it (delays must not stall
        // the other direction's mode reads).
        enum Action {
            Forward,
            DelayForward(u64),
            Drop,
            Cut(usize),
        }
        let action = {
            let mut m = mode.lock().unwrap();
            match *m {
                FaultMode::Forward | FaultMode::Refuse => Action::Forward,
                FaultMode::Delay(ms) => Action::DelayForward(ms),
                FaultMode::DropFrames(n) => {
                    *m = if n <= 1 {
                        FaultMode::Forward
                    } else {
                        FaultMode::DropFrames(n - 1)
                    };
                    if n == 0 {
                        Action::Forward
                    } else {
                        Action::Drop
                    }
                }
                FaultMode::CutAfter(budget) => Action::Cut(budget),
            }
        };
        let frame_len = HEADER_LEN + payload_len;
        match action {
            Action::Drop => continue,
            Action::Forward | Action::DelayForward(_) => {
                if let Action::DelayForward(ms) = action {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                if to.write_all(&header).is_err()
                    || to.write_all(&payload).is_err()
                    || to.flush().is_err()
                {
                    break;
                }
                forwarded += frame_len;
            }
            Action::Cut(budget) => {
                let room = budget.saturating_sub(forwarded);
                if room >= frame_len {
                    if to.write_all(&header).is_err()
                        || to.write_all(&payload).is_err()
                        || to.flush().is_err()
                    {
                        break;
                    }
                    forwarded += frame_len;
                } else {
                    // Truncate: emit exactly the bytes left in the
                    // budget — possibly mid-header — then kill.
                    let mut frame = Vec::with_capacity(frame_len);
                    frame.extend_from_slice(&header);
                    frame.extend_from_slice(&payload);
                    let _ = to.write_all(&frame[..room]);
                    let _ = to.flush();
                    break;
                }
            }
        }
    }
    sever(&from);
    sever(&to);
}

/// Shut down both directions of a stream (ignoring errors — the peer
/// may already be gone).
fn sever(s: &Stream) {
    match s {
        Stream::Tcp(t) => {
            let _ = t.shutdown(std::net::Shutdown::Both);
        }
        #[cfg(unix)]
        Stream::Unix(u) => {
            let _ = u.shutdown(std::net::Shutdown::Both);
        }
    }
}
