//! FLANN-style hierarchical k-means tree for MIPS, via the Bachrach
//! euclidean lift ([`super::transform`]). This is the index the paper's
//! §5.2 end-to-end experiment uses ("implemented by modifying the
//! implementation of K-Means Tree in FLANN").
//!
//! Build: recursively k-means the (lifted) points with branching factor
//! `b` until leaves hold ≤ `leaf_size` points.
//!
//! Search: best-bin-first traversal with a global priority queue ordered
//! by distance-to-centroid; descend to the nearest child, push siblings,
//! score leaf points exactly, and keep popping until `max_probes` points
//! have been scored. Exact scoring of visited leaves uses the *original*
//! inner product, so returned scores are exact (only *membership* of the
//! true top-k set is approximate — precisely the error mode the paper's
//! Table 3 studies).

use super::transform::MipsTransform;
use super::{select_top_k, Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Tree build/search parameters.
#[derive(Clone, Debug)]
pub struct KMeansTreeConfig {
    /// Branching factor (FLANN default 32; smaller → deeper trees).
    pub branching: usize,
    /// Max points per leaf.
    pub leaf_size: usize,
    /// Lloyd iterations per split.
    pub kmeans_iters: usize,
    /// Max points scored per query (the sublinearity knob). The effective
    /// probe budget for a query asking top-k is `max(max_probes, 4k)`.
    pub max_probes: usize,
    /// Build seed.
    pub seed: u64,
    /// Threads used by `top_k_batch` to fan traversals out. Callers that
    /// already parallelize at the request level (e.g. the coordinator's
    /// worker pool) should set 1 to avoid oversubscription.
    pub threads: usize,
}

impl Default for KMeansTreeConfig {
    fn default() -> Self {
        KMeansTreeConfig {
            branching: 32,
            leaf_size: 64,
            kmeans_iters: 6,
            max_probes: 4096,
            seed: 0,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

enum Node {
    Internal {
        /// Child centroids, row-major (children.len() × lifted_d).
        centroids: Vec<f32>,
        /// Squared norms of each child centroid (§Perf: child ordering
        /// uses the pseudo-distance ‖c‖² − 2·c·q, computed as one
        /// contiguous GEMV instead of per-child dist_sq calls).
        centroid_norms: Vec<f32>,
        children: Vec<usize>, // node ids
    },
    Leaf {
        /// Original dataset indices.
        items: Vec<usize>,
        /// The items' *original* vectors copied contiguously (items.len()
        /// × d). Leaf scoring streams this block sequentially instead of
        /// gathering scattered store rows — the single biggest search
        /// speedup in the §Perf pass (cache misses dominated before).
        block: Vec<f32>,
    },
}

/// Hierarchical k-means tree MIPS index.
pub struct KMeansTreeIndex {
    store: std::sync::Arc<EmbeddingStore>,
    transform: MipsTransform,
    nodes: Vec<Node>,
    root: usize,
    cfg: KMeansTreeConfig,
}

/// Priority-queue entry: nodes ordered by ascending distance bound.
struct QEntry {
    dist: f32,
    node: usize,
}
impl PartialEq for QEntry {
    fn eq(&self, other: &Self) -> bool {
        self.dist == other.dist
    }
}
impl Eq for QEntry {}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by distance.
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
    }
}

impl KMeansTreeIndex {
    /// Build the tree over `store`.
    pub fn build(store: &EmbeddingStore, cfg: KMeansTreeConfig) -> Self {
        Self::build_from_arc(std::sync::Arc::new(store.clone()), cfg)
    }

    /// Build over an already-`Arc`'d store (shard builds avoid the full
    /// matrix copy `build` makes).
    pub fn build_from_arc(store: std::sync::Arc<EmbeddingStore>, cfg: KMeansTreeConfig) -> Self {
        let transform = MipsTransform::lift(&store);
        let mut rng = Rng::seeded(cfg.seed);
        let mut nodes = Vec::new();
        let all: Vec<usize> = (0..store.len()).collect();
        let root = Self::build_node(&store, &transform, all, &cfg, &mut rng, &mut nodes);
        KMeansTreeIndex {
            store,
            transform,
            nodes,
            root,
            cfg,
        }
    }

    fn make_leaf(store: &EmbeddingStore, subset: Vec<usize>, nodes: &mut Vec<Node>) -> usize {
        let d = store.dim();
        let mut block = Vec::with_capacity(subset.len() * d);
        for &i in &subset {
            block.extend_from_slice(store.row(i));
        }
        nodes.push(Node::Leaf {
            items: subset,
            block,
        });
        nodes.len() - 1
    }

    fn build_node(
        store: &EmbeddingStore,
        t: &MipsTransform,
        subset: Vec<usize>,
        cfg: &KMeansTreeConfig,
        rng: &mut Rng,
        nodes: &mut Vec<Node>,
    ) -> usize {
        if subset.len() <= cfg.leaf_size || subset.len() <= cfg.branching {
            return Self::make_leaf(store, subset, nodes);
        }
        let view = super::kmeans::SubsetView {
            data: &t.lifted,
            d: t.d + 1,
            subset: &subset,
        };
        let km = super::kmeans::kmeans(&view, cfg.branching, cfg.kmeans_iters, rng);
        // Partition subset by assignment.
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); km.k];
        for (pos, &orig) in subset.iter().enumerate() {
            parts[km.assign[pos]].push(orig);
        }
        // Degenerate split (all points identical / one huge part): make a leaf.
        let nonempty = parts.iter().filter(|p| !p.is_empty()).count();
        if nonempty <= 1 {
            return Self::make_leaf(store, subset, nodes);
        }
        let mut children = Vec::new();
        let mut centroids = Vec::new();
        for (c, part) in parts.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            centroids.extend_from_slice(&km.centroids[c * km.d..(c + 1) * km.d]);
            let child = Self::build_node(store, t, part, cfg, rng, nodes);
            children.push(child);
        }
        let ld = t.d + 1;
        let centroid_norms: Vec<f32> = (0..children.len())
            .map(|c| linalg::norm_sq(&centroids[c * ld..(c + 1) * ld]))
            .collect();
        nodes.push(Node::Internal {
            centroids,
            centroid_norms,
            children,
        });
        nodes.len() - 1
    }

    /// Search with an explicit probe budget; returns exact-scored hits from
    /// the visited leaves plus the number of points actually scored.
    pub fn search_with_budget(&self, q: &[f32], k: usize, max_probes: usize) -> (Vec<Hit>, usize) {
        let lq = self.transform.lift_query(q);
        let ld = self.transform.d + 1;
        let mut heap = BinaryHeap::new();
        let mut scratch: Vec<f32> = Vec::with_capacity(self.cfg.branching);
        heap.push(QEntry {
            dist: f32::NEG_INFINITY,
            node: self.root,
        });
        let mut cand_idx: Vec<usize> = Vec::with_capacity(max_probes.min(self.store.len()));
        let mut cand_score: Vec<f32> = Vec::with_capacity(max_probes.min(self.store.len()));
        let mut probes = 0usize;
        while let Some(QEntry { node, .. }) = heap.pop() {
            if probes >= max_probes {
                break;
            }
            match &self.nodes[node] {
                Node::Leaf { items, block } => {
                    let base = cand_score.len();
                    cand_idx.extend_from_slice(items);
                    cand_score.resize(base + items.len(), 0.0);
                    linalg::gemv_blocked(
                        block,
                        items.len(),
                        self.transform.d,
                        q,
                        &mut cand_score[base..],
                    );
                    probes += items.len();
                }
                Node::Internal {
                    centroids,
                    centroid_norms,
                    children,
                } => {
                    // Pseudo-distance ‖c‖² − 2 c·q preserves the ‖c − q‖²
                    // order (the ‖q‖² term is common to every entry) and
                    // turns the per-child dist_sq into one streaming GEMV.
                    scratch.resize(children.len(), 0.0);
                    linalg::gemv_blocked(centroids, children.len(), ld, &lq, &mut scratch);
                    for (c, &child) in children.iter().enumerate() {
                        heap.push(QEntry {
                            dist: centroid_norms[c] - 2.0 * scratch[c],
                            node: child,
                        });
                    }
                }
            }
        }
        let hits = select_top_k(&cand_score, k)
            .into_iter()
            .map(|h| Hit {
                idx: cand_idx[h.idx],
                score: h.score,
            })
            .collect();
        (hits, probes)
    }

    /// Tree statistics (for DESIGN.md-style reports and tests).
    pub fn stats(&self) -> TreeStats {
        let mut leaves = 0usize;
        let mut max_leaf = 0usize;
        let mut items = 0usize;
        for n in &self.nodes {
            if let Node::Leaf { items: it, .. } = n {
                leaves += 1;
                max_leaf = max_leaf.max(it.len());
                items += it.len();
            }
        }
        TreeStats {
            nodes: self.nodes.len(),
            leaves,
            max_leaf,
            items,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreeStats {
    pub nodes: usize,
    pub leaves: usize,
    pub max_leaf: usize,
    pub items: usize,
}

impl MipsIndex for KMeansTreeIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let budget = self.cfg.max_probes.max(4 * k);
        self.search_with_budget(q, k, budget).0
    }

    /// Batched retrieval: tree traversals are independent per query, so
    /// the batch fans out across `cfg.threads` (each traversal already
    /// scores leaf blocks with the blocked SIMD GEMV).
    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        crate::util::threadpool::par_map(qs.len(), self.cfg.threads, |qi| self.top_k(&qs[qi], k))
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn probe_cost(&self, k: usize) -> usize {
        self.cfg.max_probes.max(4 * k).min(self.store.len())
    }

    fn name(&self) -> &'static str {
        "kmeans-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 3000,
            d: 24,
            clusters: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn every_item_lands_in_exactly_one_leaf() {
        let s = store();
        let idx = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        let st = idx.stats();
        assert_eq!(st.items, s.len(), "leaves must partition the dataset");
        assert!(st.leaves > 1);
    }

    #[test]
    fn full_budget_recovers_exact_topk() {
        let s = store();
        let tree = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        let brute = BruteIndex::new(&s);
        let q = s.row(100).to_vec();
        let (hits, probes) = tree.search_with_budget(&q, 10, s.len());
        assert_eq!(probes, s.len());
        let want = brute.top_k(&q, 10);
        assert_eq!(
            hits.iter().map(|h| h.idx).collect::<Vec<_>>(),
            want.iter().map(|h| h.idx).collect::<Vec<_>>()
        );
    }

    #[test]
    fn limited_budget_has_high_recall_on_clustered_data() {
        let s = store();
        let tree = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        let brute = BruteIndex::new(&s);
        let mut total_recall = 0f64;
        let queries = 20;
        for qi in 0..queries {
            // Rare (clustered) tokens: the regime MIPS indexes serve well.
            let q = s.row(s.len() - 1 - qi * 7).to_vec();
            let (hits, probes) = tree.search_with_budget(&q, 10, 600);
            assert!(probes <= 600 + 64, "probe budget respected (one leaf over)");
            let got: std::collections::HashSet<_> = hits.iter().map(|h| h.idx).collect();
            let want: std::collections::HashSet<_> =
                brute.top_k(&q, 10).iter().map(|h| h.idx).collect();
            total_recall += got.intersection(&want).count() as f64 / 10.0;
        }
        let recall = total_recall / queries as f64;
        assert!(
            recall > 0.7,
            "recall@10 {recall} too low at 20% probe budget"
        );
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let s = store();
        let tree = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        let q = s.row(5).to_vec();
        let (hits, _) = tree.search_with_budget(&q, 5, 500);
        for h in hits {
            let want = crate::linalg::dot(s.row(h.idx), &q);
            assert!((h.score - want).abs() < 1e-5);
        }
    }

    #[test]
    fn deterministic_build() {
        let s = store();
        let a = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        let b = KMeansTreeIndex::build(&s, KMeansTreeConfig::default());
        assert_eq!(a.stats(), b.stats());
        let q = s.row(0).to_vec();
        assert_eq!(
            a.top_k(&q, 5).iter().map(|h| h.idx).collect::<Vec<_>>(),
            b.top_k(&q, 5).iter().map(|h| h.idx).collect::<Vec<_>>()
        );
    }
}
