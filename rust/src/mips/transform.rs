//! The Bachrach et al. (RecSys 2014) reduction from MIPS to Euclidean
//! nearest-neighbor search, as used by the paper's §5.2 experiments
//! ("the specific MIPS algorithm presented by [3] ... implemented by
//! modifying the implementation of K-Means Tree in FLANN").
//!
//! Data vectors `v ∈ R^d` are lifted to `v* = [sqrt(Φ² − |v|²), v] ∈ R^{d+1}`
//! where `Φ = max_i |v_i|`; all lifted vectors then share the norm `Φ`.
//! A query is lifted to `q* = [0, q]`. Then
//!
//! ```text
//! |v* − q*|² = Φ² + |q|² − 2 v·q
//! ```
//!
//! so Euclidean NN order over the lifted vectors equals descending
//! inner-product order over the originals — exactly, not approximately.

use crate::data::embeddings::EmbeddingStore;
use crate::linalg;

/// The lifted dataset plus the constants needed to undo the reduction.
pub struct MipsTransform {
    /// Lifted row-major data, shape (n × (d+1)).
    pub lifted: Vec<f32>,
    pub n: usize,
    /// Original dimensionality (lifted dim = d + 1).
    pub d: usize,
    /// Φ = max row norm of the original data.
    pub phi: f32,
}

impl MipsTransform {
    /// Lift every row of `store` into R^{d+1}.
    pub fn lift(store: &EmbeddingStore) -> MipsTransform {
        let n = store.len();
        let d = store.dim();
        let phi_sq = (0..n)
            .map(|i| linalg::norm_sq(store.row(i)))
            .fold(0f32, f32::max);
        let phi = phi_sq.sqrt();
        let mut lifted = vec![0f32; n * (d + 1)];
        for i in 0..n {
            let row = store.row(i);
            let extra = (phi_sq - linalg::norm_sq(row)).max(0.0).sqrt();
            let out = &mut lifted[i * (d + 1)..(i + 1) * (d + 1)];
            out[0] = extra;
            out[1..].copy_from_slice(row);
        }
        MipsTransform { lifted, n, d, phi }
    }

    /// Lift a query: `q* = [0, q]`.
    pub fn lift_query(&self, q: &[f32]) -> Vec<f32> {
        assert_eq!(q.len(), self.d);
        let mut out = Vec::with_capacity(self.d + 1);
        out.push(0.0);
        out.extend_from_slice(q);
        out
    }

    /// The lifted row i.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.lifted[i * (self.d + 1)..(i + 1) * (self.d + 1)]
    }

    /// Recover the inner product `v_i · q` from a lifted squared distance:
    /// `v·q = (Φ² + |q|² − dist²) / 2`.
    pub fn inner_from_dist_sq(&self, dist_sq: f32, q_norm_sq: f32) -> f32 {
        0.5 * (self.phi * self.phi + q_norm_sq - dist_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::util::rng::Rng;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 500,
            d: 24,
            clusters: 8,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn lifted_rows_share_norm_phi() {
        let s = store();
        let t = MipsTransform::lift(&s);
        for i in (0..s.len()).step_by(37) {
            let nrm = linalg::norm(t.row(i));
            assert!(
                (nrm - t.phi).abs() < 1e-3 * t.phi,
                "row {i} lifted norm {nrm} != phi {}",
                t.phi
            );
        }
    }

    /// The core property: Euclidean order over lifted vectors == descending
    /// inner-product order over originals.
    #[test]
    fn distance_order_equals_inner_product_order() {
        let s = store();
        let t = MipsTransform::lift(&s);
        let mut rng = Rng::seeded(5);
        for _ in 0..5 {
            let q = rng.normal_vec(s.dim());
            let lq = t.lift_query(&q);
            let mut by_ip: Vec<usize> = (0..s.len()).collect();
            by_ip.sort_by(|&a, &b| {
                linalg::dot(s.row(b), &q)
                    .partial_cmp(&linalg::dot(s.row(a), &q))
                    .unwrap()
            });
            let mut by_dist: Vec<usize> = (0..s.len()).collect();
            by_dist.sort_by(|&a, &b| {
                linalg::dist_sq(t.row(a), &lq)
                    .partial_cmp(&linalg::dist_sq(t.row(b), &lq))
                    .unwrap()
            });
            // Compare top-20 prefix (beyond that, float ties can permute).
            assert_eq!(&by_ip[..20], &by_dist[..20]);
        }
    }

    #[test]
    fn inner_product_recoverable_from_distance() {
        let s = store();
        let t = MipsTransform::lift(&s);
        let mut rng = Rng::seeded(6);
        let q = rng.normal_vec(s.dim());
        let lq = t.lift_query(&q);
        let qn = linalg::norm_sq(&q);
        for i in (0..s.len()).step_by(61) {
            let want = linalg::dot(s.row(i), &q);
            let got = t.inner_from_dist_sq(linalg::dist_sq(t.row(i), &lq), qn);
            assert!((want - got).abs() < 2e-2 * (1.0 + want.abs()), "{want} vs {got}");
        }
    }

    #[test]
    fn max_norm_row_gets_zero_padding() {
        let s = store();
        let t = MipsTransform::lift(&s);
        // The row with the max norm has lifted[0] ≈ 0.
        let norms = s.norms();
        let (argmax, _) = norms
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert!(t.row(argmax)[0].abs() < 1e-2);
    }
}
