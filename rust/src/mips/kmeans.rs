//! k-means clustering (k-means++ seeding + Lloyd iterations) over subsets
//! of a row-major matrix. This is the building block of the FLANN-style
//! hierarchical k-means tree (`kmeans_tree`), which clusters recursively.

use crate::linalg;
use crate::util::rng::Rng;

/// Result of one k-means run over a subset of rows.
pub struct KMeansResult {
    /// Centroids, row-major (k × d). May contain fewer than requested k if
    /// the subset has fewer distinct points.
    pub centroids: Vec<f32>,
    pub k: usize,
    pub d: usize,
    /// Assignment of each input row (by position in `subset`) to a centroid.
    pub assign: Vec<usize>,
}

/// Access rows of a matrix through a subset of indices.
pub struct SubsetView<'a> {
    pub data: &'a [f32],
    pub d: usize,
    pub subset: &'a [usize],
}

impl<'a> SubsetView<'a> {
    #[inline]
    pub fn row(&self, pos: usize) -> &'a [f32] {
        let i = self.subset[pos];
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn len(&self) -> usize {
        self.subset.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subset.is_empty()
    }
}

/// k-means++ seeding: first centroid uniform, subsequent ones proportional
/// to squared distance from the nearest chosen centroid.
fn seed_pp(view: &SubsetView, k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = view.len();
    let d = view.d;
    let mut centroids = Vec::with_capacity(k * d);
    let first = rng.below(n);
    centroids.extend_from_slice(view.row(first));
    let mut dist = vec![0f32; n];
    for (pos, dst) in dist.iter_mut().enumerate() {
        *dst = linalg::dist_sq(view.row(pos), &centroids[..d]);
    }
    while centroids.len() / d < k {
        let total: f64 = dist.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for (pos, &dx) in dist.iter().enumerate() {
                target -= dx as f64;
                if target <= 0.0 {
                    chosen = pos;
                    break;
                }
            }
            chosen
        };
        let c0 = centroids.len();
        centroids.extend_from_slice(view.row(pick));
        let new_c = &centroids[c0..c0 + d].to_vec();
        for (pos, dst) in dist.iter_mut().enumerate() {
            let dnew = linalg::dist_sq(view.row(pos), new_c);
            if dnew < *dst {
                *dst = dnew;
            }
        }
    }
    centroids
}

/// Run k-means over the subset. `iters` Lloyd steps (FLANN uses a small
/// fixed count for tree builds; convergence isn't needed for good trees).
pub fn kmeans(view: &SubsetView, k: usize, iters: usize, rng: &mut Rng) -> KMeansResult {
    let n = view.len();
    let d = view.d;
    assert!(n > 0, "kmeans over empty subset");
    let k = k.min(n);
    let mut centroids = seed_pp(view, k, rng);
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // Assignment step.
        for pos in 0..n {
            let row = view.row(pos);
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let dd = linalg::dist_sq(row, &centroids[c * d..(c + 1) * d]);
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            assign[pos] = best;
        }
        // Update step.
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for pos in 0..n {
            let c = assign[pos];
            counts[c] += 1;
            let row = view.row(pos);
            for j in 0..d {
                sums[c * d + j] += row[j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: reseed at a random point.
                let pick = rng.below(n);
                centroids[c * d..(c + 1) * d].copy_from_slice(view.row(pick));
                continue;
            }
            let inv = 1.0 / counts[c] as f64;
            for j in 0..d {
                centroids[c * d + j] = (sums[c * d + j] * inv) as f32;
            }
        }
    }
    // Final assignment against final centroids.
    for pos in 0..n {
        let row = view.row(pos);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dd = linalg::dist_sq(row, &centroids[c * d..(c + 1) * d]);
            if dd < best_d {
                best_d = dd;
                best = c;
            }
        }
        assign[pos] = best;
    }
    KMeansResult {
        centroids,
        k,
        d,
        assign,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs must be recovered exactly.
    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::seeded(2);
        let d = 8;
        let centers = [10.0f32, -10.0, 30.0];
        let mut data = Vec::new();
        let mut truth = Vec::new();
        for (ci, &c) in centers.iter().enumerate() {
            for _ in 0..50 {
                for _ in 0..d {
                    data.push(c + rng.normal() as f32 * 0.1);
                }
                truth.push(ci);
            }
        }
        let subset: Vec<usize> = (0..150).collect();
        let view = SubsetView {
            data: &data,
            d,
            subset: &subset,
        };
        let res = kmeans(&view, 3, 10, &mut rng);
        // All members of a true blob share a cluster id, distinct across blobs.
        let mut blob_to_cluster = [usize::MAX; 3];
        for (pos, &t) in truth.iter().enumerate() {
            if blob_to_cluster[t] == usize::MAX {
                blob_to_cluster[t] = res.assign[pos];
            }
            assert_eq!(res.assign[pos], blob_to_cluster[t], "blob {t} split");
        }
        let uniq: std::collections::HashSet<_> = blob_to_cluster.iter().collect();
        assert_eq!(uniq.len(), 3, "blobs merged");
    }

    #[test]
    fn k_clamped_to_n() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        let subset = [0usize, 1];
        let view = SubsetView {
            data: &data,
            d: 2,
            subset: &subset,
        };
        let mut rng = Rng::seeded(0);
        let res = kmeans(&view, 10, 3, &mut rng);
        assert_eq!(res.k, 2);
        assert_eq!(res.assign.len(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut data = Vec::new();
        let mut rng = Rng::seeded(7);
        for _ in 0..200 {
            data.push(rng.normal() as f32);
        }
        let subset: Vec<usize> = (0..50).collect();
        let view = SubsetView {
            data: &data,
            d: 4,
            subset: &subset,
        };
        let a = kmeans(&view, 5, 5, &mut Rng::seeded(1));
        let b = kmeans(&view, 5, 5, &mut Rng::seeded(1));
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
    }
}
