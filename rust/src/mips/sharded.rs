//! [`ShardedIndex`]: scatter-gather MIPS over a [`ShardedStore`].
//!
//! One independent index per shard (any [`MipsIndex`] family — brute,
//! k-means tree, LSH — chosen by the builder closure). `top_k` /
//! `top_k_batch` scatter the query across shards in parallel on the
//! scoped thread pool, map each shard's local hits to global ids by
//! adding the shard offset, and merge by `(score desc, global id asc)` —
//! the exact comparator [`select_top_k`] uses, so over exact per-shard
//! indexes the merged result is identical to an unsharded exact top-k,
//! ties included (`rust/tests/sharding.rs` pins the tie ordering).
//!
//! Per-shard indexes are `Arc`-shared so epoch snapshots
//! ([`crate::store::SnapshotHandle`]) can republish untouched shards
//! without rebuilding their indexes.

use super::{Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::store::ShardedStore;
use crate::util::threadpool;
use std::sync::Arc;

/// MIPS index composed of one sub-index per contiguous shard.
pub struct ShardedIndex {
    offsets: Vec<usize>,
    indexes: Vec<Arc<dyn MipsIndex>>,
    len: usize,
    threads: usize,
}

impl ShardedIndex {
    /// Build one sub-index per shard of `store` with `build`.
    pub fn build<F>(store: &ShardedStore, build: F) -> ShardedIndex
    where
        F: Fn(&Arc<EmbeddingStore>) -> Arc<dyn MipsIndex>,
    {
        let parts: Vec<(usize, Arc<dyn MipsIndex>)> = store
            .shards()
            .iter()
            .map(|sh| (sh.offset(), build(sh.store())))
            .collect();
        Self::from_parts(parts)
    }

    /// Exact per-shard retrieval: one [`super::brute::BruteIndex`] per
    /// shard, with the scoring threads split across shards so the
    /// cross-shard scatter does not oversubscribe the machine.
    pub fn brute(store: &ShardedStore) -> ShardedIndex {
        let per_shard = per_shard_threads(store.num_shards());
        Self::build(store, |s| {
            Arc::new(super::brute::BruteIndex::from_arc_with_threads(
                s.clone(),
                per_shard,
            ))
        })
    }

    /// Assemble from `(global_offset, sub_index)` pairs in global order.
    /// Offsets must be contiguous: each shard starts where the previous
    /// one ended.
    pub fn from_parts(parts: Vec<(usize, Arc<dyn MipsIndex>)>) -> ShardedIndex {
        let mut offsets = Vec::with_capacity(parts.len());
        let mut indexes = Vec::with_capacity(parts.len());
        let mut expect = 0usize;
        for (offset, index) in parts {
            assert_eq!(
                offset, expect,
                "shard offsets must be contiguous: got {offset}, expected {expect}"
            );
            expect += index.len();
            offsets.push(offset);
            indexes.push(index);
        }
        ShardedIndex {
            offsets,
            indexes,
            len: expect,
            threads: threadpool::default_threads(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.indexes.len()
    }

    /// The sub-index serving shard `s` (for snapshot reuse).
    pub fn shard_index(&self, s: usize) -> &Arc<dyn MipsIndex> {
        &self.indexes[s]
    }

    pub fn shard_offset(&self, s: usize) -> usize {
        self.offsets[s]
    }

    /// Map one shard's local hits to global ids.
    fn globalize(offset: usize, hits: Vec<Hit>) -> Vec<Hit> {
        hits.into_iter()
            .map(|h| Hit {
                idx: h.idx + offset,
                score: h.score,
            })
            .collect()
    }
}

/// Fair scoring-thread budget for one shard of `num_shards`: the
/// cross-shard scatter runs shards concurrently, so each shard gets its
/// share of the machine instead of the full default (which would
/// oversubscribe S-fold). Shared by [`ShardedIndex::brute`] and the
/// snapshot builders.
pub fn per_shard_threads(num_shards: usize) -> usize {
    threadpool::default_threads()
        .div_ceil(num_shards.max(1))
        .max(1)
}

/// Merge already-retrieved per-shard hits into one global top-`k`: sort
/// by the canonical [`super::hit_cmp`] ordering — the comparator
/// [`select_top_k`](super::select_top_k) applies — and truncate. Every
/// global top-k member is inside its shard's local top-k, so merging
/// per-shard top-k lists loses nothing.
pub fn merge_top_k(per_shard: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = per_shard.into_iter().flatten().collect();
    all.sort_by(super::hit_cmp);
    all.truncate(k);
    all
}

impl MipsIndex for ShardedIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let per_shard = threadpool::par_map(self.indexes.len(), self.threads, |s| {
            Self::globalize(self.offsets[s], self.indexes[s].top_k(q, k))
        });
        merge_top_k(per_shard, k)
    }

    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        // Scatter: each shard answers the whole query block through its
        // own batched path (the PR 1 GEMM pass on brute sub-indexes).
        let mut per_shard: Vec<Vec<Vec<Hit>>> =
            threadpool::par_map(self.indexes.len(), self.threads, |s| {
                self.indexes[s]
                    .top_k_batch(qs, k)
                    .into_iter()
                    .map(|hits| Self::globalize(self.offsets[s], hits))
                    .collect()
            });
        // Gather: merge shard answers per query, in submission order,
        // moving each shard's hit vector out instead of cloning it.
        (0..nq)
            .map(|qi| {
                merge_top_k(
                    per_shard
                        .iter_mut()
                        .map(|shard| std::mem::take(&mut shard[qi]))
                        .collect(),
                    k,
                )
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn probe_cost(&self, k: usize) -> usize {
        self.indexes.iter().map(|i| i.probe_cost(k)).sum()
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store(n: usize) -> EmbeddingStore {
        generate(&SynthConfig {
            n,
            d: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn top_k_matches_unsharded_brute() {
        let s = store(400);
        let mono = BruteIndex::new(&s);
        let q = s.row(13).to_vec();
        let want = mono.top_k(&q, 25);
        for count in [1usize, 3, 7] {
            let sharded = ShardedIndex::brute(&ShardedStore::split(&s, count));
            let got = sharded.top_k(&q, 25);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.idx, w.idx, "shards={count}");
                assert!(
                    (g.score - w.score).abs() <= 1e-5 * (1.0 + w.score.abs()),
                    "shards={count}: {} vs {}",
                    g.score,
                    w.score
                );
            }
        }
    }

    #[test]
    fn batch_matches_single() {
        let s = store(300);
        let sharded = ShardedIndex::brute(&ShardedStore::split(&s, 4));
        let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 50 + 2).to_vec()).collect();
        let batched = sharded.top_k_batch(&qs, 12);
        for (q, hits) in qs.iter().zip(&batched) {
            assert_eq!(hits, &sharded.top_k(q, 12));
        }
        assert!(sharded.top_k_batch(&[], 5).is_empty());
    }

    #[test]
    fn merge_breaks_ties_by_global_id() {
        // Two shards return equal scores; lower global id must win, and
        // ordering must match select_top_k on the concatenated scores.
        let a = vec![
            Hit { idx: 4, score: 2.0 },
            Hit { idx: 0, score: 1.0 },
        ];
        let b = vec![
            Hit { idx: 3, score: 2.0 },
            Hit { idx: 9, score: 2.0 },
        ];
        let merged = merge_top_k(vec![a, b], 3);
        assert_eq!(
            merged.iter().map(|h| h.idx).collect::<Vec<_>>(),
            vec![3, 4, 9]
        );
    }

    #[test]
    fn len_and_probe_cost_aggregate() {
        let s = store(200);
        let sharded = ShardedIndex::brute(&ShardedStore::split(&s, 3));
        assert_eq!(sharded.len(), 200);
        assert_eq!(sharded.probe_cost(10), 200, "brute probes every row once");
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.name(), "sharded");
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn from_parts_rejects_offset_gaps() {
        let s = store(20);
        let idx: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&s));
        ShardedIndex::from_parts(vec![(5, idx)]);
    }
}
