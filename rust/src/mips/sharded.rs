//! [`ShardedIndex`]: scatter-gather MIPS over a [`ShardedStore`].
//!
//! One independent index per shard (any [`MipsIndex`] family — brute,
//! k-means tree, LSH — chosen by the builder closure). `top_k` /
//! `top_k_batch` scatter the query across shards in parallel on the
//! scoped thread pool, map each shard's local hits to global ids by
//! adding the shard offset, and merge by `(score desc, global id asc)` —
//! the exact comparator [`select_top_k`] uses, so over exact per-shard
//! indexes the merged result is identical to an unsharded exact top-k,
//! ties included (`rust/tests/sharding.rs` pins the tie ordering).
//!
//! Per-shard indexes are `Arc`-shared so epoch snapshots
//! ([`crate::store::SnapshotHandle`]) can republish untouched shards
//! without rebuilding their indexes.

use super::{Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::store::ShardedStore;
use crate::util::threadpool;
use std::sync::Arc;

/// MIPS index composed of one sub-index per contiguous shard.
pub struct ShardedIndex {
    offsets: Vec<usize>,
    indexes: Vec<Arc<dyn MipsIndex>>,
    len: usize,
    threads: usize,
}

impl ShardedIndex {
    /// Build one sub-index per shard of `store` with `build`, handing
    /// each shard its **size-proportional share** of `total_threads`
    /// ([`proportional_threads`]) — the one assembly path shared by
    /// [`ShardedIndex::brute`] and the snapshot builders.
    pub fn build<F>(store: &ShardedStore, total_threads: usize, build: F) -> ShardedIndex
    where
        F: Fn(&Arc<EmbeddingStore>, usize) -> Arc<dyn MipsIndex>,
    {
        let lens: Vec<usize> = store.shards().iter().map(|sh| sh.len()).collect();
        let budgets = proportional_threads(&lens, total_threads);
        let parts: Vec<(usize, Arc<dyn MipsIndex>)> = store
            .shards()
            .iter()
            .zip(&budgets)
            .map(|(sh, &threads)| (sh.offset(), build(sh.store(), threads)))
            .collect();
        Self::from_parts(parts)
    }

    /// Exact per-shard retrieval: one [`super::brute::BruteIndex`] per
    /// shard, with the scoring threads split across shards
    /// **proportionally to shard row counts** ([`proportional_threads`])
    /// so the cross-shard scatter neither oversubscribes the machine nor
    /// starves a large shard: the scatter's critical path is the slowest
    /// shard, and after repeated `remove_categories` epochs shard sizes
    /// diverge enough that the old even split left the biggest shard
    /// scanning `max_s len_s` rows on `T/S` threads.
    pub fn brute(store: &ShardedStore) -> ShardedIndex {
        Self::build(store, threadpool::default_threads(), |s, threads| {
            Arc::new(super::brute::BruteIndex::from_arc_with_threads(
                s.clone(),
                threads,
            ))
        })
    }

    /// Assemble from `(global_offset, sub_index)` pairs in global order.
    /// Offsets must be contiguous: each shard starts where the previous
    /// one ended.
    pub fn from_parts(parts: Vec<(usize, Arc<dyn MipsIndex>)>) -> ShardedIndex {
        let mut offsets = Vec::with_capacity(parts.len());
        let mut indexes = Vec::with_capacity(parts.len());
        let mut expect = 0usize;
        for (offset, index) in parts {
            assert_eq!(
                offset, expect,
                "shard offsets must be contiguous: got {offset}, expected {expect}"
            );
            expect += index.len();
            offsets.push(offset);
            indexes.push(index);
        }
        ShardedIndex {
            offsets,
            indexes,
            len: expect,
            threads: threadpool::default_threads(),
        }
    }

    /// Override the scatter's thread budget (default: the machine's
    /// scoring threads). Compute-bound sub-indexes want the default —
    /// oversubscribing CPU threads buys nothing — but **I/O-bound**
    /// sub-indexes (`net::remote::RemoteShardIndex`, where each call
    /// blocks on a wire round-trip) want one thread per shard
    /// regardless of core count, so every worker's RPC is in flight
    /// concurrently and the scatter's critical path is the slowest
    /// worker, not a core-limited serialization of fast ones.
    pub fn with_scatter_threads(mut self, threads: usize) -> ShardedIndex {
        self.threads = threads.max(1);
        self
    }

    pub fn num_shards(&self) -> usize {
        self.indexes.len()
    }

    /// The sub-index serving shard `s` (for snapshot reuse).
    pub fn shard_index(&self, s: usize) -> &Arc<dyn MipsIndex> {
        &self.indexes[s]
    }

    pub fn shard_offset(&self, s: usize) -> usize {
        self.offsets[s]
    }

    /// Map one shard's local hits to global ids.
    fn globalize(offset: usize, hits: Vec<Hit>) -> Vec<Hit> {
        hits.into_iter()
            .map(|h| Hit {
                idx: h.idx + offset,
                score: h.score,
            })
            .collect()
    }
}

/// Split `total` scoring threads across shards **proportionally to their
/// row counts** (largest-remainder apportionment, every shard getting at
/// least one thread). Near-equal shards degenerate to an even
/// threads-over-shards split; after repeated `remove_categories`
/// epochs shard sizes diverge, and the proportional split keeps the
/// scatter's critical path near `N / total` rows-per-thread instead of
/// letting the largest shard scan `max_s len_s` rows on a `total / S`
/// budget. Deterministic: remainder ties break toward the larger shard,
/// then the lower shard position.
pub fn proportional_threads(lens: &[usize], total: usize) -> Vec<usize> {
    let s = lens.len();
    if s == 0 {
        return vec![];
    }
    let total = total.max(1);
    let n: u128 = lens.iter().map(|&l| l as u128).sum();
    if n == 0 {
        return vec![1; s];
    }
    let mut out: Vec<usize> = lens
        .iter()
        .map(|&l| ((l as u128 * total as u128) / n) as usize)
        .collect();
    let assigned: usize = out.iter().sum();
    let mut order: Vec<usize> = (0..s).collect();
    order.sort_by_key(|&i| {
        let rem = (lens[i] as u128 * total as u128) % n;
        (std::cmp::Reverse(rem), std::cmp::Reverse(lens[i]), i)
    });
    for &i in order.iter().take(total.saturating_sub(assigned)) {
        out[i] += 1;
    }
    // Every non-empty shard scans at least on its own thread, even when
    // S > total (the scatter runs shards concurrently regardless).
    for t in &mut out {
        *t = (*t).max(1);
    }
    out
}

/// Merge already-retrieved per-shard hits into one global top-`k`: sort
/// by the canonical [`super::hit_cmp`] ordering — the comparator
/// [`select_top_k`](super::select_top_k) applies — and truncate. Every
/// global top-k member is inside its shard's local top-k, so merging
/// per-shard top-k lists loses nothing.
pub fn merge_top_k(per_shard: Vec<Vec<Hit>>, k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = per_shard.into_iter().flatten().collect();
    all.sort_by(super::hit_cmp);
    all.truncate(k);
    all
}

impl MipsIndex for ShardedIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let per_shard = threadpool::par_map(self.indexes.len(), self.threads, |s| {
            Self::globalize(self.offsets[s], self.indexes[s].top_k(q, k))
        });
        merge_top_k(per_shard, k)
    }

    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        // Scatter: each shard answers the whole query block through its
        // own batched path (the PR 1 GEMM pass on brute sub-indexes).
        let mut per_shard: Vec<Vec<Vec<Hit>>> =
            threadpool::par_map(self.indexes.len(), self.threads, |s| {
                self.indexes[s]
                    .top_k_batch(qs, k)
                    .into_iter()
                    .map(|hits| Self::globalize(self.offsets[s], hits))
                    .collect()
            });
        // Gather: merge shard answers per query, in submission order,
        // moving each shard's hit vector out instead of cloning it.
        (0..nq)
            .map(|qi| {
                merge_top_k(
                    per_shard
                        .iter_mut()
                        .map(|shard| std::mem::take(&mut shard[qi]))
                        .collect(),
                    k,
                )
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn probe_cost(&self, k: usize) -> usize {
        self.indexes.iter().map(|i| i.probe_cost(k)).sum()
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store(n: usize) -> EmbeddingStore {
        generate(&SynthConfig {
            n,
            d: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn top_k_matches_unsharded_brute() {
        let s = store(400);
        let mono = BruteIndex::new(&s);
        let q = s.row(13).to_vec();
        let want = mono.top_k(&q, 25);
        for count in [1usize, 3, 7] {
            let sharded = ShardedIndex::brute(&ShardedStore::split(&s, count));
            let got = sharded.top_k(&q, 25);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.idx, w.idx, "shards={count}");
                assert!(
                    (g.score - w.score).abs() <= 1e-5 * (1.0 + w.score.abs()),
                    "shards={count}: {} vs {}",
                    g.score,
                    w.score
                );
            }
        }
    }

    #[test]
    fn batch_matches_single() {
        let s = store(300);
        let sharded = ShardedIndex::brute(&ShardedStore::split(&s, 4));
        let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 50 + 2).to_vec()).collect();
        let batched = sharded.top_k_batch(&qs, 12);
        for (q, hits) in qs.iter().zip(&batched) {
            assert_eq!(hits, &sharded.top_k(q, 12));
        }
        assert!(sharded.top_k_batch(&[], 5).is_empty());
    }

    #[test]
    fn merge_breaks_ties_by_global_id() {
        // Two shards return equal scores; lower global id must win, and
        // ordering must match select_top_k on the concatenated scores.
        let a = vec![
            Hit { idx: 4, score: 2.0 },
            Hit { idx: 0, score: 1.0 },
        ];
        let b = vec![
            Hit { idx: 3, score: 2.0 },
            Hit { idx: 9, score: 2.0 },
        ];
        let merged = merge_top_k(vec![a, b], 3);
        assert_eq!(
            merged.iter().map(|h| h.idx).collect::<Vec<_>>(),
            vec![3, 4, 9]
        );
    }

    #[test]
    fn len_and_probe_cost_aggregate() {
        let s = store(200);
        let sharded = ShardedIndex::brute(&ShardedStore::split(&s, 3));
        assert_eq!(sharded.len(), 200);
        assert_eq!(sharded.probe_cost(10), 200, "brute probes every row once");
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.name(), "sharded");
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn from_parts_rejects_offset_gaps() {
        let s = store(20);
        let idx: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&s));
        ShardedIndex::from_parts(vec![(5, idx)]);
    }

    #[test]
    fn proportional_threads_is_size_proportional() {
        // 8 threads over a 4:2:1:1 size split → 4:2:1:1 exactly.
        assert_eq!(proportional_threads(&[400, 200, 100, 100], 8), vec![4, 2, 1, 1]);
        // Even sizes degenerate to the even split.
        assert_eq!(proportional_threads(&[100, 100, 100, 100], 8), vec![2, 2, 2, 2]);
        // Remainders go to the largest fractional share (deterministic).
        assert_eq!(proportional_threads(&[300, 200, 100], 4), vec![2, 1, 1]);
    }

    #[test]
    fn proportional_threads_floors_at_one_per_shard() {
        // More shards than threads: every shard still gets a thread.
        assert_eq!(proportional_threads(&[10, 10, 10], 2), vec![1, 1, 1]);
        // A tiny shard next to a huge one keeps its minimum.
        let split = proportional_threads(&[10_000, 1], 8);
        assert_eq!(split.len(), 2);
        assert!(split[0] >= 7 && split[1] == 1, "{split:?}");
        // Degenerate inputs.
        assert_eq!(proportional_threads(&[], 8), Vec::<usize>::new());
        assert_eq!(proportional_threads(&[0, 0], 8), vec![1, 1]);
    }

    #[test]
    fn proportional_threads_conserves_total_when_feasible() {
        // With S ≤ total and no starved shards, the budget is spent
        // exactly (largest-remainder apportionment conserves the total).
        for (lens, total) in [
            (vec![503usize, 251, 119], 16usize),
            (vec![600, 300, 100], 10),
            (vec![64, 32, 16, 8], 10),
        ] {
            let split = proportional_threads(&lens, total);
            assert_eq!(split.iter().sum::<usize>(), total, "{lens:?} → {split:?}");
            assert!(split.iter().all(|&t| t >= 1));
        }
    }

    #[test]
    fn brute_assigns_threads_without_changing_results() {
        // The proportional split must not change retrieval semantics,
        // only thread budgets: skewed shard sizes still answer exactly.
        let s = store(330);
        let stores = vec![
            Arc::new(
                EmbeddingStore::from_data(256, 16, s.rows(0, 256).to_vec()).unwrap(),
            ),
            Arc::new(
                EmbeddingStore::from_data(60, 16, s.rows(256, 316).to_vec()).unwrap(),
            ),
            Arc::new(
                EmbeddingStore::from_data(14, 16, s.rows(316, 330).to_vec()).unwrap(),
            ),
        ];
        let sharded = ShardedIndex::brute(&ShardedStore::from_stores(stores).unwrap());
        let mono = BruteIndex::new(&s);
        let q = s.row(5).to_vec();
        let want = mono.top_k(&q, 20);
        let got = sharded.top_k(&q, 20);
        assert_eq!(
            got.iter().map(|h| h.idx).collect::<Vec<_>>(),
            want.iter().map(|h| h.idx).collect::<Vec<_>>()
        );
    }
}
