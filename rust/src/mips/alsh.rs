//! Asymmetric LSH for MIPS (Shrivastava & Li, NIPS 2014) — the other
//! indexing family the paper builds on ("[21, 22] and [17] presented
//! methods for MIPS based on Asymmetric LSH").
//!
//! L2-ALSH(m, U, r): scale all data vectors by `U / max‖x‖` so norms are
//! < U < 1, then append `m` asymmetric augmentations
//!
//! ```text
//! P(x) = [Ux;  ‖Ux‖²,  ‖Ux‖⁴, …, ‖Ux‖^{2m}]      (data)
//! Q(q) = [q/‖q‖;  1/2,  1/2, …, 1/2]             (query)
//! ```
//!
//! after which `‖P(x) − Q(q)‖²` is monotone in `−x·q` (up to the
//! vanishing `‖Ux‖^{2^{m+1}}` term), so classical E2LSH (p-stable random
//! projections with bucket width `r`) over the augmented vectors answers
//! MIPS queries. Candidates are exactly re-scored with true inner
//! products, as in the other indexes.

use super::{select_top_k, Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// L2-ALSH parameters (paper defaults m=3, U=0.83, r=2.5).
#[derive(Clone, Debug)]
pub struct AlshConfig {
    pub m: usize,
    pub u: f32,
    pub r: f32,
    pub tables: usize,
    /// Concatenated hash functions per table.
    pub hashes_per_table: usize,
    pub seed: u64,
}

impl Default for AlshConfig {
    fn default() -> Self {
        AlshConfig {
            m: 3,
            u: 0.83,
            r: 2.5,
            tables: 16,
            hashes_per_table: 6,
            seed: 0,
        }
    }
}

struct Table {
    /// Projections (hashes_per_table × aug_d) + offsets (hashes_per_table).
    projs: Vec<f32>,
    offsets: Vec<f32>,
    buckets: HashMap<u64, Vec<u32>>,
}

/// L2-ALSH index.
pub struct AlshIndex {
    store: std::sync::Arc<EmbeddingStore>,
    /// Augmented data vectors, row-major (n × aug_d).
    augmented: Vec<f32>,
    aug_d: usize,
    scale: f32,
    tables: Vec<Table>,
    cfg: AlshConfig,
}

impl AlshIndex {
    pub fn build(store: &EmbeddingStore, cfg: AlshConfig) -> Self {
        let n = store.len();
        let d = store.dim();
        let aug_d = d + cfg.m;
        let max_norm = (0..n)
            .map(|i| linalg::norm(store.row(i)))
            .fold(0f32, f32::max)
            .max(f32::MIN_POSITIVE);
        let scale = cfg.u / max_norm;
        // Augment data: [Ux; ‖Ux‖², ‖Ux‖⁴, …].
        let mut augmented = vec![0f32; n * aug_d];
        for i in 0..n {
            let row = store.row(i);
            let out = &mut augmented[i * aug_d..(i + 1) * aug_d];
            let mut norm_sq = 0f32;
            for j in 0..d {
                let v = row[j] * scale;
                out[j] = v;
                norm_sq += v * v;
            }
            let mut pow = norm_sq;
            for j in 0..cfg.m {
                out[d + j] = pow;
                pow = pow * pow;
            }
        }
        // Hash tables: p-stable (gaussian) projections with offsets.
        let mut rng = Rng::seeded(cfg.seed ^ 0xA15);
        let mut tables = Vec::with_capacity(cfg.tables);
        for _ in 0..cfg.tables {
            let projs: Vec<f32> = (0..cfg.hashes_per_table * aug_d)
                .map(|_| rng.normal() as f32)
                .collect();
            let offsets: Vec<f32> = (0..cfg.hashes_per_table)
                .map(|_| rng.f32() * cfg.r)
                .collect();
            let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
            for i in 0..n {
                let h = Self::hash_vec(
                    &projs,
                    &offsets,
                    cfg.hashes_per_table,
                    aug_d,
                    cfg.r,
                    &augmented[i * aug_d..(i + 1) * aug_d],
                );
                buckets.entry(h).or_default().push(i as u32);
            }
            tables.push(Table {
                projs,
                offsets,
                buckets,
            });
        }
        AlshIndex {
            store: std::sync::Arc::new(store.clone()),
            augmented,
            aug_d,
            scale,
            tables,
            cfg,
        }
    }

    fn hash_vec(
        projs: &[f32],
        offsets: &[f32],
        hashes: usize,
        aug_d: usize,
        r: f32,
        x: &[f32],
    ) -> u64 {
        // Combine the `hashes` E2LSH slots into one u64 bucket key.
        let mut key = 0xcbf29ce484222325u64; // FNV offset
        for h in 0..hashes {
            let p = &projs[h * aug_d..(h + 1) * aug_d];
            let slot = ((linalg::dot(p, x) + offsets[h]) / r).floor() as i64;
            key ^= slot as u64;
            key = key.wrapping_mul(0x100000001b3);
        }
        key
    }

    /// Query transform: [q/‖q‖; 1/2, …, 1/2].
    fn augment_query(&self, q: &[f32]) -> Vec<f32> {
        let d = self.store.dim();
        let norm = linalg::norm(q).max(f32::MIN_POSITIVE);
        let mut out = Vec::with_capacity(self.aug_d);
        for &v in q {
            out.push(v / norm);
        }
        out.extend(std::iter::repeat(0.5f32).take(self.cfg.m));
        debug_assert_eq!(out.len(), d + self.cfg.m);
        out
    }

    fn candidates(&self, q: &[f32]) -> Vec<u32> {
        let aq = self.augment_query(q);
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in &self.tables {
            let h = Self::hash_vec(
                &t.projs,
                &t.offsets,
                self.cfg.hashes_per_table,
                self.aug_d,
                self.cfg.r,
                &aq,
            );
            if let Some(items) = t.buckets.get(&h) {
                for &i in items {
                    if seen.insert(i) {
                        out.push(i);
                    }
                }
            }
        }
        out
    }

    /// The data scaling factor U/max‖x‖ (diagnostics).
    pub fn scale(&self) -> f32 {
        self.scale
    }
}

impl MipsIndex for AlshIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let cands = self.candidates(q);
        let scores: Vec<f32> = cands
            .iter()
            .map(|&i| linalg::dot(self.store.row(i as usize), q))
            .collect();
        select_top_k(&scores, k)
            .into_iter()
            .map(|h| Hit {
                idx: cands[h.idx] as usize,
                score: h.score,
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn probe_cost(&self, _k: usize) -> usize {
        // Expected candidates per table ≈ collision probability mass; use
        // the empirical mean bucket size × tables as the estimate.
        let mean_bucket: f64 = self
            .tables
            .iter()
            .map(|t| self.store.len() as f64 / t.buckets.len().max(1) as f64)
            .sum::<f64>()
            / self.tables.len().max(1) as f64;
        ((mean_bucket * self.cfg.tables as f64) as usize).clamp(1, self.store.len())
    }

    fn name(&self) -> &'static str {
        "l2-alsh"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 2000,
            d: 24,
            clusters: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn augmentation_shapes_and_scaling() {
        let s = store();
        let idx = AlshIndex::build(&s, AlshConfig::default());
        // Every scaled data norm must be < U.
        for i in (0..s.len()).step_by(97) {
            let row = &idx.augmented[i * idx.aug_d..i * idx.aug_d + s.dim()];
            assert!(linalg::norm(row) <= idx.cfg.u + 1e-4);
        }
        // Augmented tail follows ‖Ux‖^{2^j}.
        let i = 123;
        let base = &idx.augmented[i * idx.aug_d..i * idx.aug_d + s.dim()];
        let nsq = linalg::norm_sq(base);
        let tail = &idx.augmented[i * idx.aug_d + s.dim()..(i + 1) * idx.aug_d];
        assert!((tail[0] - nsq).abs() < 1e-5);
        assert!((tail[1] - nsq * nsq).abs() < 1e-5);
    }

    #[test]
    fn buckets_partition_per_table() {
        let s = store();
        let idx = AlshIndex::build(&s, AlshConfig::default());
        for t in &idx.tables {
            let total: usize = t.buckets.values().map(|v| v.len()).sum();
            assert_eq!(total, s.len());
        }
    }

    #[test]
    fn reasonable_recall_on_clustered_data() {
        let s = store();
        let idx = AlshIndex::build(&s, AlshConfig::default());
        let brute = BruteIndex::new(&s);
        let mut recall = 0f64;
        let queries = 20;
        for qi in 0..queries {
            let q = s.row(s.len() - 1 - qi * 9).to_vec();
            let got: std::collections::HashSet<_> =
                idx.top_k(&q, 10).iter().map(|h| h.idx).collect();
            let want: std::collections::HashSet<_> =
                brute.top_k(&q, 10).iter().map(|h| h.idx).collect();
            recall += got.intersection(&want).count() as f64 / 10.0;
        }
        recall /= queries as f64;
        assert!(recall > 0.3, "ALSH recall@10 = {recall}");
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let s = store();
        let idx = AlshIndex::build(&s, AlshConfig::default());
        let q = s.row(42).to_vec();
        for h in idx.top_k(&q, 5) {
            let want = linalg::dot(s.row(h.idx), &q);
            assert!((h.score - want).abs() < 1e-5);
        }
    }

    #[test]
    fn probe_cost_sublinear() {
        let s = store();
        let idx = AlshIndex::build(&s, AlshConfig::default());
        assert!(idx.probe_cost(10) < s.len());
    }
}
