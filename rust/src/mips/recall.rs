//! Recall@k measurement of an approximate MIPS index against the exact
//! brute-force oracle — the quantity that (per the paper's §5.1
//! retrieval-error study) governs estimator quality, and the axis along
//! which indexing schemes should be compared.

use super::{brute::BruteIndex, MipsIndex};
use crate::util::rng::Rng;

/// Result of a recall sweep.
#[derive(Clone, Debug)]
pub struct RecallReport {
    pub k: usize,
    pub queries: usize,
    /// Mean fraction of the true top-k recovered.
    pub recall: f64,
    /// Fraction of queries whose true top-1 was recovered (Table 3 shows
    /// missing rank-1 is the expensive failure).
    pub top1_recall: f64,
    /// Mean probe cost per query reported by the index.
    pub mean_probes: f64,
}

/// Measure recall@k of `index` against `brute` on `queries` random data
/// vectors (self-queries, matching the paper's query construction).
pub fn measure<I: MipsIndex + ?Sized>(
    index: &I,
    brute: &BruteIndex,
    k: usize,
    queries: usize,
    rng: &mut Rng,
) -> RecallReport {
    let n = brute.len();
    let mut recall_sum = 0f64;
    let mut top1_sum = 0f64;
    let mut probes = 0usize;
    for _ in 0..queries {
        let qi = rng.below(n);
        let q = brute.store().row(qi).to_vec();
        let want = brute.top_k(&q, k);
        let got: std::collections::HashSet<usize> =
            index.top_k(&q, k).iter().map(|h| h.idx).collect();
        let inter = want.iter().filter(|h| got.contains(&h.idx)).count();
        recall_sum += inter as f64 / k as f64;
        top1_sum += if got.contains(&want[0].idx) { 1.0 } else { 0.0 };
        probes += index.probe_cost(k);
    }
    RecallReport {
        k,
        queries,
        recall: recall_sum / queries as f64,
        top1_recall: top1_sum / queries as f64,
        mean_probes: probes as f64 / queries as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};

    #[test]
    fn brute_vs_brute_is_perfect() {
        let s = generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(1);
        let r = measure(&brute, &brute, 10, 5, &mut rng);
        assert_eq!(r.recall, 1.0);
        assert_eq!(r.top1_recall, 1.0);
        assert_eq!(r.mean_probes, 500.0);
    }

    #[test]
    fn tree_recall_between_zero_and_one() {
        let s = generate(&SynthConfig {
            n: 1000,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let tree = KMeansTreeIndex::build(
            &s,
            KMeansTreeConfig {
                max_probes: 200,
                ..Default::default()
            },
        );
        let mut rng = Rng::seeded(2);
        let r = measure(&tree, &brute, 10, 10, &mut rng);
        assert!(r.recall > 0.0 && r.recall <= 1.0);
        assert!(r.mean_probes < 1000.0, "tree should probe sublinearly");
    }
}
