//! Signed-random-projection (SimHash) LSH index for MIPS, again via the
//! Bachrach lift — the alternative indexing family the paper discusses
//! (Shrivastava & Li's ALSH, Neyshabur & Srebro). After the lift all data
//! points share norm Φ, so cosine LSH over lifted vectors hashes by the
//! same geometry the Euclidean search uses, and exact rescoring of
//! candidate buckets returns exact inner products.
//!
//! Multi-table + multiprobe: `tables` independent hash tables of `bits`
//! hyperplanes each; probing flips up to `probe_flips` of the lowest-margin
//! bits to visit adjacent buckets, trading probes for recall.

use super::transform::MipsTransform;
use super::{select_top_k, Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// LSH parameters.
#[derive(Clone, Debug)]
pub struct LshConfig {
    /// Number of independent hash tables.
    pub tables: usize,
    /// Hyperplanes (bits) per table; buckets = 2^bits.
    pub bits: usize,
    /// Number of low-margin bit flips to multiprobe per table.
    pub probe_flips: usize,
    pub seed: u64,
}

impl Default for LshConfig {
    fn default() -> Self {
        LshConfig {
            tables: 8,
            bits: 12,
            probe_flips: 6,
            seed: 0,
        }
    }
}

struct Table {
    /// Hyperplanes, row-major (bits × lifted_d).
    planes: Vec<f32>,
    buckets: HashMap<u64, Vec<u32>>,
}

/// SimHash LSH MIPS index.
pub struct SimHashIndex {
    store: std::sync::Arc<EmbeddingStore>,
    transform: MipsTransform,
    tables: Vec<Table>,
    cfg: LshConfig,
}

impl SimHashIndex {
    pub fn build(store: &EmbeddingStore, cfg: LshConfig) -> Self {
        Self::build_from_arc(std::sync::Arc::new(store.clone()), cfg)
    }

    /// Build over an already-`Arc`'d store (shard builds avoid the full
    /// matrix copy `build` makes).
    pub fn build_from_arc(store: std::sync::Arc<EmbeddingStore>, cfg: LshConfig) -> Self {
        let transform = MipsTransform::lift(&store);
        let ld = transform.d + 1;
        let mut rng = Rng::seeded(cfg.seed ^ 0x5151_5151);
        let mut tables = Vec::with_capacity(cfg.tables);
        for _ in 0..cfg.tables {
            let planes: Vec<f32> = (0..cfg.bits * ld)
                .map(|_| rng.normal() as f32)
                .collect();
            let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
            for i in 0..store.len() {
                let h = Self::hash(&planes, cfg.bits, ld, transform.row(i)).0;
                buckets.entry(h).or_default().push(i as u32);
            }
            tables.push(Table { planes, buckets });
        }
        SimHashIndex {
            store,
            transform,
            tables,
            cfg,
        }
    }

    /// Hash a lifted vector; also return per-bit margins |p·x| for multiprobe.
    fn hash(planes: &[f32], bits: usize, ld: usize, x: &[f32]) -> (u64, Vec<f32>) {
        let mut h = 0u64;
        let mut margins = Vec::with_capacity(bits);
        for b in 0..bits {
            let p = &planes[b * ld..(b + 1) * ld];
            let s = linalg::dot(p, x);
            if s >= 0.0 {
                h |= 1 << b;
            }
            margins.push(s.abs());
        }
        (h, margins)
    }

    /// Candidate set for a query (deduplicated across tables and probes).
    fn candidates(&self, q: &[f32]) -> Vec<u32> {
        let lq = self.transform.lift_query(q);
        let ld = self.transform.d + 1;
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in &self.tables {
            let (h, margins) = Self::hash(&t.planes, self.cfg.bits, ld, &lq);
            // Primary bucket + flips of the lowest-margin bits.
            let mut order: Vec<usize> = (0..self.cfg.bits).collect();
            order.sort_by(|&a, &b| margins[a].partial_cmp(&margins[b]).unwrap());
            let mut probe_hashes = vec![h];
            for &b in order.iter().take(self.cfg.probe_flips) {
                probe_hashes.push(h ^ (1 << b));
            }
            for ph in probe_hashes {
                if let Some(items) = t.buckets.get(&ph) {
                    for &i in items {
                        if seen.insert(i) {
                            out.push(i);
                        }
                    }
                }
            }
        }
        out
    }
}

impl MipsIndex for SimHashIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let cands = self.candidates(q);
        let scores: Vec<f32> = cands
            .iter()
            .map(|&i| linalg::dot(self.store.row(i as usize), q))
            .collect();
        select_top_k(&scores, k)
            .into_iter()
            .map(|h| Hit {
                idx: cands[h.idx] as usize,
                score: h.score,
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn probe_cost(&self, _k: usize) -> usize {
        // Expected candidates: tables * (1 + flips) * N / 2^bits, capped at N.
        let per_bucket = self.store.len() as f64 / (1u64 << self.cfg.bits) as f64;
        let est = (self.cfg.tables * (1 + self.cfg.probe_flips)) as f64 * per_bucket;
        (est as usize).min(self.store.len()).max(1)
    }

    fn name(&self) -> &'static str {
        "simhash-lsh"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 2000,
            d: 24,
            clusters: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn buckets_partition_dataset_per_table() {
        let s = store();
        let idx = SimHashIndex::build(&s, LshConfig::default());
        for t in &idx.tables {
            let total: usize = t.buckets.values().map(|v| v.len()).sum();
            assert_eq!(total, s.len());
        }
    }

    #[test]
    fn returned_scores_exact() {
        let s = store();
        let idx = SimHashIndex::build(&s, LshConfig::default());
        let q = s.row(10).to_vec();
        for h in idx.top_k(&q, 5) {
            let want = linalg::dot(s.row(h.idx), &q);
            assert!((h.score - want).abs() < 1e-5);
        }
    }

    #[test]
    fn self_query_finds_itself() {
        let s = store();
        let idx = SimHashIndex::build(&s, LshConfig::default());
        // A rare (large-norm, clustered) vector queries for itself: it has
        // the max inner product with itself among near-duplicates, and the
        // same hash in every table, so it must be in the candidates.
        let i = s.len() - 1;
        let q = s.row(i).to_vec();
        let hits = idx.top_k(&q, 1);
        assert_eq!(hits[0].idx, i);
    }

    #[test]
    fn reasonable_recall_at_k10() {
        let s = store();
        let idx = SimHashIndex::build(&s, LshConfig::default());
        let brute = BruteIndex::new(&s);
        let mut recall = 0f64;
        let queries = 20;
        for qi in 0..queries {
            let q = s.row(s.len() - 1 - qi * 11).to_vec();
            let got: std::collections::HashSet<_> =
                idx.top_k(&q, 10).iter().map(|h| h.idx).collect();
            let want: std::collections::HashSet<_> =
                brute.top_k(&q, 10).iter().map(|h| h.idx).collect();
            recall += got.intersection(&want).count() as f64 / 10.0;
        }
        recall /= queries as f64;
        assert!(recall > 0.5, "LSH recall@10 = {recall}");
    }

    #[test]
    fn probe_cost_sublinear_at_default_config() {
        let s = store();
        let idx = SimHashIndex::build(&s, LshConfig::default());
        assert!(idx.probe_cost(10) < s.len());
    }
}
