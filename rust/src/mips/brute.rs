//! Exact top-k MIPS by blocked linear scan — the ground-truth oracle for
//! every estimator experiment and the brute-force baseline that Table 4's
//! Speedup column divides against. Parallelized over row blocks.

use super::{select_top_k, Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::util::threadpool;

/// Exact MIPS index (stores a reference-counted copy of the matrix).
pub struct BruteIndex {
    data: std::sync::Arc<EmbeddingStore>,
    threads: usize,
}

impl BruteIndex {
    pub fn new(store: &EmbeddingStore) -> Self {
        Self::from_arc(std::sync::Arc::new(store.clone()))
    }

    pub fn with_threads(store: &EmbeddingStore, threads: usize) -> Self {
        Self::from_arc_with_threads(std::sync::Arc::new(store.clone()), threads)
    }

    /// Share an already-`Arc`'d store (shard builds avoid the full
    /// matrix copy `new` makes).
    pub fn from_arc(store: std::sync::Arc<EmbeddingStore>) -> Self {
        Self::from_arc_with_threads(store, threadpool::default_threads())
    }

    pub fn from_arc_with_threads(store: std::sync::Arc<EmbeddingStore>, threads: usize) -> Self {
        BruteIndex {
            data: store,
            threads: threads.max(1),
        }
    }

    /// Score all N categories against `q` into `out` (no allocation).
    pub fn score_all(&self, q: &[f32], out: &mut [f32]) {
        let n = self.data.len();
        let d = self.data.dim();
        assert_eq!(out.len(), n);
        let data = &self.data;
        threadpool::par_chunks_mut(out, self.threads, |start, slice| {
            linalg::gemv_blocked(
                data.rows(start, start + slice.len()),
                slice.len(),
                d,
                q,
                slice,
            );
        });
    }

    /// Exact partition function Z(q) = Σ exp(v_i · q), computed in f64 with
    /// per-thread partial sums over the fused SIMD exp-sum kernel. This is
    /// the ground truth for every table.
    pub fn partition(&self, q: &[f32]) -> f64 {
        let n = self.data.len();
        let d = self.data.dim();
        let data = &self.data;
        threadpool::par_fold(
            n,
            self.threads,
            |range| linalg::exp_sum_gemv(data.rows(range.start, range.end), range.len(), d, q),
            0f64,
            |a, b| a + b,
        )
    }

    /// Score all N categories against a whole query block (`qs_flat` is
    /// row-major nq × d) into `out` (row-major N × nq), one multi-query
    /// GEMM per row block so each streamed category row is reused across
    /// the entire batch.
    pub fn score_all_batch(&self, qs_flat: &[f32], nq: usize, out: &mut [f32]) {
        let n = self.data.len();
        let d = self.data.dim();
        assert_eq!(qs_flat.len(), nq * d);
        assert_eq!(out.len(), n * nq);
        let data = &self.data;
        threadpool::par_row_chunks_mut(out, nq, self.threads, |first_row, block| {
            let rows = block.len() / nq;
            linalg::gemm(
                data.rows(first_row, first_row + rows),
                rows,
                d,
                qs_flat,
                nq,
                block,
            );
        });
    }

    /// Batched exact partition: Z(q) for every query in `qs` from one
    /// blocked GEMM pass over the category matrix, parallel over row
    /// ranges with per-thread partial sums.
    pub fn partition_batch(&self, qs: &[Vec<f32>]) -> Vec<f64> {
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        let n = self.data.len();
        let d = self.data.dim();
        let qs_flat = linalg::flatten_queries(qs, d);
        let data = &self.data;
        threadpool::par_fold(
            n,
            self.threads,
            |range| {
                let mut acc = vec![0f64; nq];
                linalg::exp_sum_gemm(
                    data.rows(range.start, range.end),
                    range.len(),
                    d,
                    &qs_flat,
                    nq,
                    &mut acc,
                );
                acc
            },
            vec![0f64; nq],
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
        )
    }

    pub fn store(&self) -> &EmbeddingStore {
        &self.data
    }
}

impl MipsIndex for BruteIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let mut scores = vec![0f32; self.data.len()];
        self.score_all(q, &mut scores);
        select_top_k(&scores, k)
    }

    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        let n = self.data.len();
        let d = self.data.dim();
        let qs_flat = linalg::flatten_queries(qs, d);
        let mut scores = vec![0f32; n * nq];
        self.score_all_batch(&qs_flat, nq, &mut scores);
        // Per-query selection over the strided score columns, in parallel.
        let scores = &scores;
        threadpool::par_map(nq, self.threads, |qi| {
            let mut col = vec![0f32; n];
            for (r, c) in col.iter_mut().enumerate() {
                *c = scores[r * nq + qi];
            }
            select_top_k(&col, k)
        })
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn probe_cost(&self, _k: usize) -> usize {
        self.data.len()
    }

    fn name(&self) -> &'static str {
        "brute"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::util::rng::Rng;

    fn tiny_store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 300,
            d: 16,
            clusters: 4,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn top_k_matches_naive_sort() {
        let s = tiny_store();
        let idx = BruteIndex::new(&s);
        let mut rng = Rng::seeded(3);
        let q = rng.normal_vec(16);
        let hits = idx.top_k(&q, 10);
        // Naive: full sort.
        let mut scored: Vec<(usize, f32)> = (0..s.len())
            .map(|i| (i, linalg::dot(s.row(i), &q)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (h, (i, sc)) in hits.iter().zip(scored.iter().take(10)) {
            assert_eq!(h.idx, *i);
            assert!((h.score - sc).abs() < 1e-6);
        }
    }

    #[test]
    fn partition_matches_direct_sum() {
        let s = tiny_store();
        let idx = BruteIndex::new(&s);
        let q = s.row(5).to_vec();
        let z = idx.partition(&q);
        let direct: f64 = (0..s.len())
            .map(|i| (linalg::dot(s.row(i), &q) as f64).exp())
            .sum();
        assert!((z - direct).abs() < 1e-9 * direct, "{z} vs {direct}");
    }

    #[test]
    fn single_thread_matches_multi() {
        let s = tiny_store();
        let a = BruteIndex::with_threads(&s, 1);
        let b = BruteIndex::with_threads(&s, 8);
        let q = s.row(0).to_vec();
        assert!((a.partition(&q) - b.partition(&q)).abs() < 1e-9 * a.partition(&q));
        assert_eq!(a.top_k(&q, 5), b.top_k(&q, 5));
    }

    #[test]
    fn probe_cost_is_linear() {
        let s = tiny_store();
        let idx = BruteIndex::new(&s);
        assert_eq!(idx.probe_cost(10), s.len());
    }
}
