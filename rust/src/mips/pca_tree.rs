//! PCA-tree MIPS index (Sproull 1991, cited by the paper as one of the
//! retrieval options for `S_k(q)`), over the Bachrach lift.
//!
//! Build: at each node, compute the principal component of the (lifted)
//! points by power iteration, split at the median projection, recurse.
//! Search: best-bin-first with a priority queue keyed by the *projection
//! margin* to the splitting hyperplane — the lower bound on the distance
//! a point on the far side can have.

use super::transform::MipsTransform;
use super::{select_top_k, Hit, MipsIndex};
use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// PCA-tree parameters.
#[derive(Clone, Debug)]
pub struct PcaTreeConfig {
    pub leaf_size: usize,
    /// Power-iteration steps for the principal component.
    pub power_iters: usize,
    /// Max points scored per query.
    pub max_probes: usize,
    pub seed: u64,
}

impl Default for PcaTreeConfig {
    fn default() -> Self {
        PcaTreeConfig {
            leaf_size: 64,
            power_iters: 8,
            max_probes: 4096,
            seed: 0,
        }
    }
}

enum Node {
    Split {
        /// Unit principal direction (lifted dim).
        dir: Vec<f32>,
        /// Median projection value.
        threshold: f32,
        left: usize,
        right: usize,
    },
    Leaf {
        items: Vec<usize>,
    },
}

/// The PCA tree.
pub struct PcaTreeIndex {
    store: std::sync::Arc<EmbeddingStore>,
    transform: MipsTransform,
    nodes: Vec<Node>,
    root: usize,
    cfg: PcaTreeConfig,
}

/// Principal component of the subset via centered power iteration.
fn principal_direction(
    data: &[f32],
    ld: usize,
    subset: &[usize],
    iters: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    // Mean.
    let mut mean = vec![0f64; ld];
    for &i in subset {
        let row = &data[i * ld..(i + 1) * ld];
        for j in 0..ld {
            mean[j] += row[j] as f64;
        }
    }
    let inv = 1.0 / subset.len() as f64;
    for m in &mut mean {
        *m *= inv;
    }
    // Power iteration on the covariance (implicitly: v ← Σ (x−μ)((x−μ)·v)).
    let mut v = rng.unit_vec(ld);
    for _ in 0..iters {
        let mut next = vec![0f64; ld];
        for &i in subset {
            let row = &data[i * ld..(i + 1) * ld];
            let mut proj = 0f64;
            for j in 0..ld {
                proj += (row[j] as f64 - mean[j]) * v[j] as f64;
            }
            for j in 0..ld {
                next[j] += (row[j] as f64 - mean[j]) * proj;
            }
        }
        let norm = next.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm < 1e-30 {
            break; // degenerate: all points identical
        }
        for j in 0..ld {
            v[j] = (next[j] / norm) as f32;
        }
    }
    v
}

impl PcaTreeIndex {
    pub fn build(store: &EmbeddingStore, cfg: PcaTreeConfig) -> Self {
        let transform = MipsTransform::lift(store);
        let ld = transform.d + 1;
        let mut rng = Rng::seeded(cfg.seed ^ 0x9CA);
        let mut nodes = Vec::new();
        let all: Vec<usize> = (0..store.len()).collect();
        let root = Self::build_node(&transform.lifted, ld, all, &cfg, &mut rng, &mut nodes);
        PcaTreeIndex {
            store: std::sync::Arc::new(store.clone()),
            transform,
            nodes,
            root,
            cfg,
        }
    }

    fn build_node(
        data: &[f32],
        ld: usize,
        subset: Vec<usize>,
        cfg: &PcaTreeConfig,
        rng: &mut Rng,
        nodes: &mut Vec<Node>,
    ) -> usize {
        if subset.len() <= cfg.leaf_size {
            nodes.push(Node::Leaf { items: subset });
            return nodes.len() - 1;
        }
        let dir = principal_direction(data, ld, &subset, cfg.power_iters, rng);
        let mut projs: Vec<(usize, f32)> = subset
            .iter()
            .map(|&i| (i, linalg::dot(&data[i * ld..(i + 1) * ld], &dir)))
            .collect();
        projs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal));
        let mid = projs.len() / 2;
        let threshold = projs[mid].1;
        let left_items: Vec<usize> = projs[..mid].iter().map(|(i, _)| *i).collect();
        let right_items: Vec<usize> = projs[mid..].iter().map(|(i, _)| *i).collect();
        if left_items.is_empty() || right_items.is_empty() {
            nodes.push(Node::Leaf { items: subset });
            return nodes.len() - 1;
        }
        let left = Self::build_node(data, ld, left_items, cfg, rng, nodes);
        let right = Self::build_node(data, ld, right_items, cfg, rng, nodes);
        nodes.push(Node::Split {
            dir,
            threshold,
            left,
            right,
        });
        nodes.len() - 1
    }

    /// Best-bin-first search with an explicit probe budget.
    pub fn search_with_budget(&self, q: &[f32], k: usize, max_probes: usize) -> (Vec<Hit>, usize) {
        struct QE {
            bound: f32,
            node: usize,
        }
        impl PartialEq for QE {
            fn eq(&self, o: &Self) -> bool {
                self.bound == o.bound
            }
        }
        impl Eq for QE {}
        impl PartialOrd for QE {
            fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for QE {
            fn cmp(&self, o: &Self) -> Ordering {
                o.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
            }
        }
        let lq = self.transform.lift_query(q);
        let mut heap = BinaryHeap::new();
        heap.push(QE {
            bound: 0.0,
            node: self.root,
        });
        let mut cand_idx = Vec::new();
        let mut cand_score = Vec::new();
        let mut probes = 0usize;
        while let Some(QE { node, .. }) = heap.pop() {
            if probes >= max_probes {
                break;
            }
            match &self.nodes[node] {
                Node::Leaf { items } => {
                    for &i in items {
                        cand_idx.push(i);
                        cand_score.push(linalg::dot(self.store.row(i), q));
                    }
                    probes += items.len();
                }
                Node::Split {
                    dir,
                    threshold,
                    left,
                    right,
                } => {
                    let proj = linalg::dot(dir, &lq);
                    let margin = (proj - threshold).abs();
                    let (near, far) = if proj < *threshold {
                        (*left, *right)
                    } else {
                        (*right, *left)
                    };
                    heap.push(QE {
                        bound: 0.0,
                        node: near,
                    });
                    heap.push(QE {
                        bound: margin,
                        node: far,
                    });
                }
            }
        }
        let hits = select_top_k(&cand_score, k)
            .into_iter()
            .map(|h| Hit {
                idx: cand_idx[h.idx],
                score: h.score,
            })
            .collect();
        (hits, probes)
    }

    /// Number of leaves (diagnostics).
    pub fn leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }
}

impl MipsIndex for PcaTreeIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let budget = self.cfg.max_probes.max(4 * k);
        self.search_with_budget(q, k, budget).0
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn probe_cost(&self, k: usize) -> usize {
        self.cfg.max_probes.max(4 * k).min(self.store.len())
    }

    fn name(&self) -> &'static str {
        "pca-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 2000,
            d: 24,
            clusters: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn leaves_partition_dataset() {
        let s = store();
        let t = PcaTreeIndex::build(&s, PcaTreeConfig::default());
        let mut total = 0usize;
        for n in &t.nodes {
            if let Node::Leaf { items } = n {
                total += items.len();
            }
        }
        assert_eq!(total, s.len());
        assert!(t.leaves() > 1);
    }

    #[test]
    fn full_budget_recovers_exact_topk() {
        let s = store();
        let t = PcaTreeIndex::build(&s, PcaTreeConfig::default());
        let brute = BruteIndex::new(&s);
        let q = s.row(77).to_vec();
        let (hits, _) = t.search_with_budget(&q, 10, s.len());
        let want = brute.top_k(&q, 10);
        assert_eq!(
            hits.iter().map(|h| h.idx).collect::<Vec<_>>(),
            want.iter().map(|h| h.idx).collect::<Vec<_>>()
        );
    }

    #[test]
    fn limited_budget_reasonable_recall() {
        let s = store();
        let t = PcaTreeIndex::build(&s, PcaTreeConfig::default());
        let brute = BruteIndex::new(&s);
        let mut recall = 0f64;
        let queries = 15;
        for qi in 0..queries {
            let q = s.row(s.len() - 1 - qi * 13).to_vec();
            let got: std::collections::HashSet<_> = t
                .search_with_budget(&q, 10, 400)
                .0
                .iter()
                .map(|h| h.idx)
                .collect();
            let want: std::collections::HashSet<_> =
                brute.top_k(&q, 10).iter().map(|h| h.idx).collect();
            recall += got.intersection(&want).count() as f64 / 10.0;
        }
        recall /= queries as f64;
        assert!(recall > 0.6, "pca-tree recall@10 {recall} at 20% budget");
    }

    #[test]
    fn principal_direction_finds_dominant_axis() {
        // Points stretched along axis 0: the PC must align with it.
        let mut rng = Rng::seeded(4);
        let mut data = Vec::new();
        for _ in 0..200 {
            data.push(rng.normal() as f32 * 10.0);
            for _ in 1..4 {
                data.push(rng.normal() as f32 * 0.1);
            }
        }
        let subset: Vec<usize> = (0..200).collect();
        let dir = principal_direction(&data, 4, &subset, 10, &mut rng);
        assert!(
            dir[0].abs() > 0.99,
            "PC should align with the stretched axis: {dir:?}"
        );
    }
}
