//! Maximum Inner Product Search (MIPS) substrate.
//!
//! The paper's estimators all start from `S_k(q)` — the k categories with
//! the largest inner product against the query (Section 3). This module
//! provides:
//!
//! * [`brute::BruteIndex`] — exact top-k by blocked scan (the oracle; also
//!   the brute-force baseline that "Speedup" in Table 4 is measured against),
//! * [`transform`] — the Bachrach et al. (2014) reduction from MIPS over
//!   `R^d` to Euclidean NN over `R^{d+1}`,
//! * [`kmeans_tree::KMeansTreeIndex`] — FLANN-style hierarchical k-means
//!   tree over the transformed vectors (the index the paper's §5.2 uses),
//! * [`lsh::SimHashIndex`] — multi-table signed-random-projection LSH,
//!   the alternative indexing family the paper cites (Shrivastava & Li,
//!   Neyshabur & Srebro),
//! * [`sharded::ShardedIndex`] — scatter-gather composition: one
//!   sub-index per shard of a [`crate::store::ShardedStore`], merged by
//!   global id with [`select_top_k`]-compatible tie-breaking,
//! * [`recall`] — recall@k measurement against the exact oracle.

pub mod alsh;
pub mod brute;
pub mod kmeans;
pub mod kmeans_tree;
pub mod lsh;
pub mod pca_tree;
pub mod recall;
pub mod sharded;
pub mod transform;

/// A scored hit: category index + inner product with the query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub idx: usize,
    pub score: f32,
}

/// Common interface for all MIPS indexes.
pub trait MipsIndex: Send + Sync {
    /// Return (up to) the top-`k` categories by inner product with `q`,
    /// sorted by descending score. Approximate indexes may miss true
    /// members of `S_k(q)`; `recall` quantifies that.
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit>;

    /// Batched retrieval: top-`k` for every query in `qs`, in order. The
    /// default loops over [`MipsIndex::top_k`]; batch-aware indexes
    /// override it to share one scoring pass across the query block
    /// (`BruteIndex` via the multi-query GEMM, `KMeansTreeIndex` via
    /// parallel traversal).
    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        qs.iter().map(|q| self.top_k(q, k)).collect()
    }

    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate number of candidate scorings performed for one query at
    /// this index's current settings — the paper's sublinearity argument
    /// is about this count staying ≪ N.
    fn probe_cost(&self, k: usize) -> usize;

    /// Short identifier for reports.
    fn name(&self) -> &'static str;
}

/// The canonical hit ordering: descending score, ties toward the lower
/// id, incomparable (NaN) scores treated as equal. Shared by
/// [`select_top_k`]'s final sort and [`sharded::merge_top_k`] so the
/// cross-shard merge can never drift from the monolithic ordering.
pub fn hit_cmp(a: &Hit, b: &Hit) -> std::cmp::Ordering {
    b.score
        .partial_cmp(&a.score)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then_with(|| a.idx.cmp(&b.idx))
}

/// Select the top-k hits from a scored slice (descending), in O(n log k).
pub fn select_top_k(scores: &[f32], k: usize) -> Vec<Hit> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    // Min-heap of (score, idx) via Reverse-style wrapper on partial floats.
    #[derive(PartialEq)]
    struct Entry(f32, usize);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, other: &Self) -> Ordering {
            // Reverse on score → BinaryHeap becomes a min-heap by score.
            other
                .0
                .partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then_with(|| other.1.cmp(&self.1))
        }
    }

    let k = k.min(scores.len());
    if k == 0 {
        return vec![];
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(s, i));
        } else if s > heap.peek().unwrap().0 {
            heap.pop();
            heap.push(Entry(s, i));
        }
    }
    let mut hits: Vec<Hit> = heap
        .into_iter()
        .map(|Entry(score, idx)| Hit { idx, score })
        .collect();
    hits.sort_by(hit_cmp);
    hits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_top_k_orders_descending() {
        let scores = [1.0f32, 5.0, 3.0, 4.0, 2.0];
        let hits = select_top_k(&scores, 3);
        assert_eq!(
            hits.iter().map(|h| h.idx).collect::<Vec<_>>(),
            vec![1, 3, 2]
        );
    }

    #[test]
    fn select_top_k_handles_k_ge_n() {
        let scores = [1.0f32, 2.0];
        let hits = select_top_k(&scores, 10);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].idx, 1);
    }

    #[test]
    fn select_top_k_zero() {
        assert!(select_top_k(&[1.0], 0).is_empty());
        assert!(select_top_k(&[], 3).is_empty());
    }

    #[test]
    fn select_top_k_ties_stable_by_index() {
        let scores = [2.0f32, 2.0, 2.0, 1.0];
        let hits = select_top_k(&scores, 2);
        assert_eq!(
            hits.iter().map(|h| h.idx).collect::<Vec<_>>(),
            vec![0, 1],
            "ties break toward lower index"
        );
    }
}
