//! Shard-equivalence suite for the epoch-snapshotted sharded store:
//!
//! * `Exact` over a `ShardedStore` is **bit-identical** to the unsharded
//!   answer for every shard count (the `store::exp_sum_view` streaming
//!   contract), single and batched.
//! * Sampler estimators (`Mimps`, `Fmbe`) agree across shard counts
//!   under a fixed seed (global tail draws depend only on seed + head
//!   membership; FMBE's feature draw depends only on seed + d).
//! * `ShardedIndex::top_k` merge ordering matches `select_top_k`'s
//!   global-id tie-break exactly, exercised with duplicated rows at
//!   d = 8 (one full SIMD lane group — every scalar/AVX2 kernel path
//!   accumulates a d=8 row in the same order, so duplicate rows tie
//!   bit-exactly on every backend).
//! * `add_categories` publishes a new epoch while in-flight service
//!   batches keep answering from the snapshot they pinned.

use std::sync::Arc;
use zest::coordinator::{EstimateSpec, PartitionService, Router, ServiceConfig};
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::fmbe::{Fmbe, FmbeConfig};
use zest::estimators::mimps::Mimps;
use zest::estimators::{exact::Exact, tail, EstimateContext, Estimator};
use zest::mips::brute::BruteIndex;
use zest::mips::sharded::ShardedIndex;
use zest::mips::MipsIndex;
use zest::store::{exp_sum_view, ShardedStore, SnapshotHandle, StoreView};
use zest::util::rng::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn store(n: usize, d: usize) -> EmbeddingStore {
    generate(&SynthConfig {
        n,
        d,
        ..SynthConfig::tiny()
    })
}

/// Exact Z is bit-identical across shard counts, for the single-query
/// and the batched path (acceptance criterion).
#[test]
fn exact_bit_identical_across_shard_counts() {
    let s = store(503, 17);
    let qs: Vec<Vec<f32>> = (0..6).map(|i| s.row(i * 80 + 3).to_vec()).collect();
    let mono = BruteIndex::new(&s);
    let want: Vec<f64> = {
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Exact.estimate_batch(&mut ctx, &qs)
    };
    for count in SHARD_COUNTS {
        let sharded = ShardedStore::split(&s, count);
        let index = ShardedIndex::brute(&sharded);
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&sharded, &index, &mut rng);
        let batched = Exact.estimate_batch(&mut ctx, &qs);
        for (qi, (got, want)) in batched.iter().zip(&want).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "shards={count} q{qi}: batched {got} vs {want}"
            );
        }
        for (qi, q) in qs.iter().enumerate() {
            let got = Exact.estimate(&mut ctx, q);
            let single_want = exp_sum_view(&s, q);
            assert_eq!(
                got.to_bits(),
                single_want.to_bits(),
                "shards={count} q{qi}: single {got} vs {single_want}"
            );
        }
    }
}

/// MIMPS under a fixed seed agrees across shard counts: the global tail
/// draw consumes the RNG identically for identical head membership, so
/// only last-ulp head-score accumulation differences (scalar backend)
/// separate the answers.
#[test]
fn mimps_agrees_across_shard_counts_under_fixed_seed() {
    let s = store(700, 24);
    let est = Mimps::new(60, 40);
    let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 130 + 7).to_vec()).collect();
    let mono = BruteIndex::new(&s);
    let want: Vec<f64> = {
        let mut rng = Rng::seeded(42);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        qs.iter().map(|q| est.estimate(&mut ctx, q)).collect()
    };
    for count in SHARD_COUNTS {
        let sharded = ShardedStore::split(&s, count);
        let index = ShardedIndex::brute(&sharded);
        let mut rng = Rng::seeded(42);
        let mut ctx = EstimateContext::new(&sharded, &index, &mut rng);
        for (qi, (q, want)) in qs.iter().zip(&want).enumerate() {
            let got = est.estimate(&mut ctx, q);
            assert!(
                (got - want).abs() <= 1e-4 * want.abs(),
                "shards={count} q{qi}: {got} vs {want}"
            );
        }
    }
}

/// FMBE fitted over a sharded view is the same estimator as over the
/// monolithic matrix: identical feature draw (seed + d only) and
/// identical λ̃ accumulation (global row order).
#[test]
fn fmbe_identical_across_shard_counts_under_fixed_seed() {
    let s = store(300, 16);
    let cfg = FmbeConfig {
        p_features: 400,
        threads: 2,
        ..Default::default()
    };
    let mono = Fmbe::fit(&s, cfg.clone());
    let q = s.row(123).to_vec();
    let want = mono.estimate_query(&q);
    for count in SHARD_COUNTS {
        let sharded = ShardedStore::split(&s, count);
        let fitted = Fmbe::fit(&sharded, cfg.clone());
        let got = fitted.estimate_query(&q);
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "shards={count}: {got} vs {want}"
        );
    }
}

/// Merge-ordering property: with duplicated rows (exact score ties on
/// every backend at d = 8), `ShardedIndex::top_k` must reproduce the
/// monolithic `select_top_k` ordering — descending score, global-id
/// tie-break — for every shard count and seed.
#[test]
fn merge_ordering_matches_select_top_k_on_ties() {
    let d = 8usize;
    for seed in 0..10u64 {
        let mut rng = Rng::seeded(seed);
        // 8 distinct prototype vectors, 64 rows drawn from them → heavy
        // exact ties within and across shard boundaries.
        let protos: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
        let n = 64usize;
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend_from_slice(&protos[rng.below(protos.len())]);
        }
        let s = EmbeddingStore::from_data(n, d, data).unwrap();
        let mono = BruteIndex::new(&s);
        let q = rng.normal_vec(d);
        for k in [1usize, 5, 16, 64] {
            let want = mono.top_k(&q, k);
            for count in SHARD_COUNTS {
                let sharded = ShardedIndex::brute(&ShardedStore::split(&s, count));
                let got = sharded.top_k(&q, k);
                assert_eq!(
                    got, want,
                    "seed={seed} k={k} shards={count}: tie ordering diverged"
                );
            }
        }
    }
}

/// Stratified tail sampling: deterministic under a seed, every shard's
/// complement is represented, and the estimator stays unbiased.
#[test]
fn stratified_tail_is_deterministic_covering_and_unbiased() {
    let s = store(800, 16);
    let sharded = ShardedStore::split(&s, 4);
    let index = ShardedIndex::brute(&sharded);
    let q = s.row(650).to_vec();
    let head = index.top_k(&q, 50);

    // Coverage + determinism of the raw stratified draw.
    let mut scratch = tail::TailScratch::new();
    let mut rng = Rng::seeded(3);
    let z_a = tail::stratified_tail_z(&sharded, &head, 40, &q, &mut rng, &mut scratch);
    let drawn_a = scratch.indices.clone();
    for sh in sharded.shards() {
        let (lo, hi) = (sh.offset(), sh.offset() + sh.len());
        assert!(
            drawn_a.iter().any(|&i| i >= lo && i < hi),
            "shard [{lo},{hi}) unrepresented in stratified draw"
        );
    }
    let mut rng = Rng::seeded(3);
    let z_b = tail::stratified_tail_z(&sharded, &head, 40, &q, &mut rng, &mut scratch);
    assert_eq!(z_a.to_bits(), z_b.to_bits(), "same seed, same draw");
    assert_eq!(drawn_a, scratch.indices);

    // Unbiasedness of the full stratified MIMPS against the exact Z.
    let want = exp_sum_view(&s, &q);
    let est = Mimps::stratified(100, 60);
    let mut rng = Rng::seeded(11);
    let mut acc = 0f64;
    let reps = 200;
    for _ in 0..reps {
        let mut ctx = EstimateContext::new(&sharded, &index, &mut rng);
        acc += est.estimate(&mut ctx, &q);
    }
    let mean = acc / reps as f64;
    let rel = ((mean - want) / want).abs();
    assert!(rel < 0.05, "stratified MIMPS mean {mean} vs Z {want} ({rel})");
}

/// Acceptance: `add_categories` publishes a new epoch while in-flight
/// service batches complete against the snapshot they pinned. Every
/// response's Z must bit-match the exact answer of the epoch it reports
/// — regardless of where the swap lands relative to the drain — and
/// requests submitted after the swap must answer from the new epoch.
#[test]
fn epoch_swap_concurrent_with_inflight_batches() {
    let s = store(3000, 32);
    let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&s, 4)));
    let svc = PartitionService::start_sharded(
        handle.clone(),
        Router::new(FmbeConfig::default()),
        ServiceConfig {
            workers: 1,
            ..Default::default()
        },
        None,
    );
    let q = s.row(5).to_vec();
    let z_epoch0 = exp_sum_view(handle.load().store.as_ref(), &q);
    assert_eq!(z_epoch0.to_bits(), exp_sum_view(&s, &q).to_bits());
    // Service answers ride the batched kernel; compare to the single-
    // query reference with an epoch-separating tolerance. The two fused
    // paths are bit-identical on AVX2 but the scalar GEMM accumulates
    // f32 in a different order than the GEMV (same 1e-6 bound as
    // tests/batching.rs uses for that comparison) — the bit-level
    // sharding guarantee is pinned like-for-like in
    // `exact_bit_identical_across_shard_counts`.
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * b.abs();

    let submit = |count: usize| {
        (0..count)
            .map(|_| {
                svc.submit(EstimateSpec::new(q.clone())).unwrap()
            })
            .collect::<Vec<_>>()
    };
    // Flood the single worker, swap the epoch mid-drain, keep flooding.
    let first = submit(24);
    let added = generate(&SynthConfig {
        n: 200,
        d: 32,
        seed: 77,
        ..SynthConfig::tiny()
    });
    assert_eq!(handle.add_categories(added).unwrap(), 1);
    let z_epoch1 = exp_sum_view(handle.load().store.as_ref(), &q);
    assert!(z_epoch1 > z_epoch0);
    let second = submit(24);

    // The witness for "in-flight batches answer from their pinned
    // snapshot" is the per-epoch Z match: whichever side of the swap a
    // batch lands on, its Z must be the one its reported epoch implies
    // — a service that mixed category sets mid-swap would produce a Z
    // matching neither reference.
    for rx in first {
        let r = rx.recv().unwrap();
        let want = if r.epoch == 0 { z_epoch0 } else { z_epoch1 };
        assert!(
            close(r.z, want),
            "epoch {} response must answer from its pinned snapshot: {} vs {want}",
            r.epoch,
            r.z
        );
    }
    for rx in second {
        let r = rx.recv().unwrap();
        assert_eq!(r.epoch, 1, "post-swap submissions see the new epoch");
        assert!(close(r.z, z_epoch1), "{} vs {z_epoch1}", r.z);
    }
    let m = svc.metrics();
    assert_eq!(m.epoch, 1);
    assert_eq!(m.completed, 48);
    assert!(
        !m.shard_stats.is_empty(),
        "sharded serving exports per-shard metrics"
    );
    svc.shutdown();
}

/// The sharded service validates dimensionality at submit() against the
/// snapshot's store.
#[test]
fn sharded_service_rejects_dim_mismatch_at_submit() {
    let s = store(100, 16);
    let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&s, 2)));
    let svc = PartitionService::start_sharded(
        handle,
        Router::new(FmbeConfig::default()),
        ServiceConfig::default(),
        None,
    );
    let err = svc.submit(EstimateSpec::new(vec![0.0; 3])).unwrap_err();
    assert_eq!(
        err,
        zest::coordinator::SubmitError::DimMismatch { got: 3, want: 16 }
    );
    svc.shutdown();
}

/// Removal keeps serving: ids compact, Z drops by exactly the removed
/// rows' mass, and untouched shards keep their indexes.
#[test]
fn remove_categories_republishes_consistent_snapshot() {
    let s = store(400, 16);
    let handle = SnapshotHandle::brute(ShardedStore::split(&s, 4));
    let q = s.row(9).to_vec();
    let before = handle.load();
    let z_before = exp_sum_view(before.store.as_ref(), &q);
    // Remove 10 ids from shard 2 (global 200..300).
    let ids: Vec<usize> = (230..240).collect();
    let removed_mass: f64 = ids
        .iter()
        .map(|&i| (zest::linalg::dot(s.row(i), &q) as f64).exp())
        .sum();
    handle.remove_categories(&ids).unwrap();
    let after = handle.load();
    assert_eq!(after.epoch, 1);
    assert_eq!(StoreView::len(after.store.as_ref()), 390);
    let z_after = exp_sum_view(after.store.as_ref(), &q);
    // 1e-6: the dot()-based reference mass can differ from the streamed
    // kernel's per-row scores in the last ulp on the scalar backend.
    assert!(
        (z_before - z_after - removed_mass).abs() <= 1e-6 * z_before,
        "Z must drop by the removed mass: {z_before} - {z_after} != {removed_mass}"
    );
    // Retrieval still works over the republished index set.
    let hits = after.index.top_k(&q, 10);
    assert_eq!(hits.len(), 10);
    for h in &hits {
        assert!(h.idx < 390);
    }
}

/// Snapshot Arc reuse, pinned end to end: across `add_categories` and
/// `remove_categories` epochs, every untouched shard's **store** and
/// **index** are pointer-identical (`Arc::ptr_eq`) to the previous
/// snapshot's — category mutations rebuild exactly the shards they
/// touch, nothing else.
#[test]
fn untouched_shards_are_arc_reused_across_epochs() {
    let s = store(400, 16);
    let handle = SnapshotHandle::brute(ShardedStore::split(&s, 4)); // shards of 100
    let e0 = handle.load();

    // add_categories: every existing shard reused, one new shard built.
    let added = generate(&SynthConfig {
        n: 40,
        d: 16,
        seed: 77,
        ..SynthConfig::tiny()
    });
    handle.add_categories(added).unwrap();
    let e1 = handle.load();
    assert_eq!(e1.store.num_shards(), 5);
    for sh in 0..4 {
        assert!(
            Arc::ptr_eq(e0.store.shard(sh).store(), e1.store.shard(sh).store()),
            "add: shard {sh} store must be Arc-reused"
        );
        assert!(
            Arc::ptr_eq(e0.index.shard_index(sh), e1.index.shard_index(sh)),
            "add: shard {sh} index must be Arc-reused"
        );
    }
    assert!(
        !Arc::ptr_eq(e0.store.shard(0).store(), e1.store.shard(4).store()),
        "the appended shard is new storage"
    );

    // remove_categories from shard 1 only: shards 0, 2, 3 and the added
    // shard 4 all keep their exact allocations (stores and indexes),
    // shard 1 is rebuilt.
    handle.remove_categories(&[150, 151, 152]).unwrap();
    let e2 = handle.load();
    assert_eq!(StoreView::len(e2.store.as_ref()), 437);
    for sh in [0usize, 2, 3, 4] {
        assert!(
            Arc::ptr_eq(e1.store.shard(sh).store(), e2.store.shard(sh).store()),
            "remove: shard {sh} store must be Arc-reused"
        );
        assert!(
            Arc::ptr_eq(e1.index.shard_index(sh), e2.index.shard_index(sh)),
            "remove: shard {sh} index must be Arc-reused"
        );
    }
    assert!(
        !Arc::ptr_eq(e1.store.shard(1).store(), e2.store.shard(1).store()),
        "remove: the touched shard's store is rebuilt"
    );
    assert!(
        !Arc::ptr_eq(e1.index.shard_index(1), e2.index.shard_index(1)),
        "remove: the touched shard's index is rebuilt"
    );
    // Offsets shifted but content preserved: old global 153 is now 150.
    assert_eq!(StoreView::row(e2.store.as_ref(), 150), s.row(153));
}
