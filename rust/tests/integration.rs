//! Cross-module integration tests: PJRT artifacts vs native numerics,
//! service end-to-end over real indexes, and full experiment smoke runs.

use std::path::PathBuf;
use std::sync::Arc;
use zest::config::Config;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::EstimatorKind;
use zest::mips::brute::BruteIndex;
use zest::mips::MipsIndex;
use zest::runtime::{spawn_runtime_thread, ArtifactsMeta, HostTensor};
use zest::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("meta.json").exists().then_some(dir)
}

/// The AOT-compiled Pallas scoring graph must agree with the native Rust
/// linalg path to float tolerance — the core L1/L2 ⇄ L3 contract.
#[test]
fn pjrt_partition_chunk_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let meta = ArtifactsMeta::load(&dir).unwrap();
    let chunk = meta.config_usize("chunk").unwrap();
    let d = meta.config_usize("d").unwrap();
    let store = generate(&SynthConfig {
        n: chunk,
        d,
        ..Default::default()
    });
    let mut rng = Rng::seeded(42);
    let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.2).collect();

    // Native.
    let mut scores = vec![0f32; chunk];
    zest::linalg::gemv_blocked(store.data(), chunk, d, &q, &mut scores);
    let native = zest::linalg::sum_exp(&scores);

    // PJRT.
    let (rt, join) =
        spawn_runtime_thread(dir, Some(vec!["partition_chunk".to_string()])).unwrap();
    let out = rt
        .run(
            "partition_chunk",
            vec![
                HostTensor::f32(store.data().to_vec(), &[chunk, d]),
                HostTensor::f32(q, &[d]),
            ],
        )
        .unwrap();
    let pjrt = out[0].first_f64().unwrap();
    rt.shutdown();
    join.join().unwrap();

    let rel = ((pjrt - native) / native).abs();
    assert!(rel < 1e-4, "pjrt {pjrt} vs native {native} (rel {rel})");
}

/// score_chunk (per-category exp scores) agrees elementwise with native.
#[test]
fn pjrt_score_chunk_matches_native_elementwise() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let meta = ArtifactsMeta::load(&dir).unwrap();
    let chunk = meta.config_usize("chunk").unwrap();
    let d = meta.config_usize("d").unwrap();
    let store = generate(&SynthConfig {
        n: chunk,
        d,
        ..Default::default()
    });
    let q = store.row(17).to_vec();
    let (rt, join) = spawn_runtime_thread(dir, Some(vec!["score_chunk".to_string()])).unwrap();
    let out = rt
        .run(
            "score_chunk",
            vec![
                HostTensor::f32(store.data().to_vec(), &[chunk, d]),
                HostTensor::f32(q.clone(), &[d]),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    rt.shutdown();
    join.join().unwrap();
    for i in (0..chunk).step_by(997) {
        let want = (zest::linalg::dot(store.row(i), &q)).exp();
        let rel = ((got[i] - want) / want.max(1e-20)).abs();
        assert!(rel < 1e-3, "row {i}: {} vs {want}", got[i]);
    }
}

/// Exact requests through the service with a PJRT runtime attached must
/// match the native brute-force partition (batched artifact path).
#[test]
fn service_exact_via_pjrt_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let meta = ArtifactsMeta::load(&dir).unwrap();
    let d = meta.config_usize("d").unwrap();
    // N not a multiple of chunk exercises the padding correction.
    let store = Arc::new(generate(&SynthConfig {
        n: 10_000,
        d,
        ..Default::default()
    }));
    std::env::set_var("ZEST_ARTIFACTS", dir.to_str().unwrap());
    let (rt, join) =
        spawn_runtime_thread(dir.clone(), Some(vec!["score_batch".to_string()])).unwrap();
    let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
    let svc = zest::coordinator::PartitionService::start(
        store.clone(),
        index,
        zest::coordinator::Router::new(Default::default()),
        zest::coordinator::ServiceConfig {
            workers: 1,
            ..Default::default()
        },
        Some(rt.clone()),
    );
    let brute = BruteIndex::new(&store);
    for qi in [0usize, 5000, 9999] {
        let q = store.row(qi).to_vec();
        let want = brute.partition(&q);
        let resp = svc
            .estimate(zest::coordinator::EstimateSpec::new(q))
            .unwrap();
        let rel = ((resp.z - want) / want).abs();
        assert!(rel < 1e-3, "qi={qi}: pjrt-exact {} vs {want}", resp.z);
    }
    svc.shutdown();
    rt.shutdown();
    join.join().unwrap();
}

/// Service over the k-means tree: MIMPS responses stay within sane error
/// of the truth for rare queries, under concurrency.
#[test]
fn service_mimps_over_tree_index() {
    let store = Arc::new(generate(&SynthConfig {
        n: 5_000,
        d: 32,
        ..SynthConfig::tiny()
    }));
    let index: Arc<dyn MipsIndex> = Arc::new(
        zest::mips::kmeans_tree::KMeansTreeIndex::build(&store, Default::default()),
    );
    let svc = Arc::new(zest::coordinator::PartitionService::start(
        store.clone(),
        index,
        zest::coordinator::Router::new(Default::default()),
        Default::default(),
        None,
    ));
    let brute = BruteIndex::new(&store);
    let mut errs = Vec::new();
    for qi in (4000..5000).step_by(100) {
        let q = store.row(qi).to_vec();
        let want = brute.partition(&q);
        let r = svc
            .estimate(
                zest::coordinator::EstimateSpec::new(q)
                    .kind(EstimatorKind::Mimps)
                    .k(100)
                    .l(100),
            )
            .unwrap();
        errs.push(zest::metrics::abs_rel_err_pct(r.z, want));
    }
    let mean = zest::metrics::mean(&errs);
    assert!(mean < 20.0, "service MIMPS mean err {mean}%");
}

/// Full experiment smoke: tables run end-to-end on a tiny config and
/// produce well-formed JSON.
#[test]
fn experiments_smoke_and_json_wellformed() {
    let store = generate(&SynthConfig::tiny());
    let cfg = Config {
        n: store.len(),
        d: store.dim(),
        queries: 20,
        seeds: 2,
        k: 200,
        l: 200,
        threads: 4,
        ..Config::smoke()
    };
    let t1 = zest::experiments::table1::run(&store, &cfg, &[200]);
    let j = zest::experiments::table1::to_json(&t1).to_string();
    assert!(zest::util::json::Json::parse(&j).is_ok());
    let t3 = zest::experiments::table3::run(&store, &cfg);
    let j = zest::experiments::table3::to_json(&t3).to_string();
    assert!(zest::util::json::Json::parse(&j).is_ok());
    let curves = zest::experiments::figure1::run(
        &store,
        &SynthConfig::tiny(),
        4,
    );
    let j = zest::experiments::figure1::to_json(&curves).to_string();
    assert!(zest::util::json::Json::parse(&j).is_ok());
}

/// Embedding store round-trips through disk and feeds an index correctly.
#[test]
fn store_disk_roundtrip_feeds_index() {
    let store = generate(&SynthConfig {
        n: 500,
        d: 16,
        ..SynthConfig::tiny()
    });
    let dir = std::env::temp_dir().join("zest_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("store.bin");
    store.save(&path).unwrap();
    let loaded = zest::data::embeddings::EmbeddingStore::load(&path).unwrap();
    let a = BruteIndex::new(&store);
    let b = BruteIndex::new(&loaded);
    let q = store.row(3).to_vec();
    assert_eq!(a.top_k(&q, 10), b.top_k(&q, 10));
    std::fs::remove_file(path).ok();
}
