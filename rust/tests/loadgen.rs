//! Open-loop load generator acceptance:
//!
//! * **Coordinated-omission regression**: a server stalling 100ms per
//!   request must NOT depress the offered rate — every scheduled
//!   arrival is dispatched, and the measured latency of late answers
//!   reflects the stall (closed-loop generators fail both).
//! * The arrival schedule and workload mix replay exactly under a
//!   seed, and the user-key draw matches `util::rng::Zipf`
//!   frequencies.
//! * A self-spawned cluster harness (`loadgen::ClusterHarness`)
//!   survives a healthy open-loop run with **zero failed requests**
//!   while a writer publishes add/remove epochs mid-run.
//! * Hedged `TopK` reads: with one replica's link delayed past the
//!   hedge delay, reads complete via the fast replica and the `hedges`
//!   counter ticks — visible in `shard_stats` and the cluster blob.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use zest::coordinator::EstimateSpec;
use zest::coordinator::ServiceMetrics;
use zest::estimators::EstimatorKind;
use zest::loadgen::{
    default_classes, find_knee, run_open_loop, Arrival, ClusterHarness, HarnessConfig, RunConfig,
    Schedule, WorkloadMix,
};
use zest::net::client::{ClientConfig, PartitionClient};
use zest::net::server::{Handler, Server, ServerConfig};
use zest::net::wire::{Estimate, Request, Response};
use zest::net::Addr;
use zest::testing::fault::FaultMode;
use zest::util::rng::Rng;

fn loopback() -> Addr {
    Addr::parse("tcp://127.0.0.1:0").unwrap()
}

/// Answers every estimate after a fixed stall — the pathological
/// server shape that makes closed-loop generators lie.
struct StallingHandler {
    stall: Duration,
    answered: AtomicU64,
}

impl Handler for StallingHandler {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Manifest => Response::Manifest { len: 1, dim: 4, epoch: 0 },
            Request::Estimate { kind, .. } => {
                std::thread::sleep(self.stall);
                self.answered.fetch_add(1, Ordering::Relaxed);
                Response::Estimates(vec![Estimate {
                    z: 1.0,
                    kind,
                    epoch: 0,
                    scorings: 0,
                    queue_wait_ns: 0,
                    exec_ns: self.stall.as_nanos() as u64,
                    served_from_cache: false,
                }])
            }
            _ => Response::Error {
                code: zest::net::wire::ErrorCode::Unsupported,
                message: "stall handler".to_string(),
            },
        }
    }
}

/// ACCEPTANCE: open-loop offered rate is independent of server speed.
/// 100 arrivals/s against a 100ms-stalling server with only 8 sessions
/// can *settle* at most ~80/s — but every scheduled arrival must still
/// be dispatched on time, and the latency histogram must show the
/// queueing the stall caused (measured from scheduled arrival).
#[test]
fn stalled_server_does_not_depress_offered_rate() {
    let stall = Duration::from_millis(100);
    let handler = Arc::new(StallingHandler { stall, answered: AtomicU64::new(0) });
    let server = Server::serve(
        &loopback(),
        handler.clone(),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let client = Arc::new(
        PartitionClient::connect(server.local_addr().clone(), ClientConfig::for_sessions(8))
            .unwrap(),
    );
    // Exact-only mix: the stall handler answers any kind; Exact skips
    // k/l validation client-side.
    let classes = vec![zest::loadgen::MixClass {
        name: "exact",
        kind: EstimatorKind::Exact,
        k: 0,
        l: 0,
        precision: Default::default(),
        deadline: None,
        weight: 1.0,
    }];
    let mix = Arc::new(WorkloadMix::new(50, 1.1, 4, classes, 3));
    let cfg = RunConfig {
        rate_hz: 100.0,
        duration: Duration::from_millis(1000),
        sessions: 8,
        arrival: Arrival::Fixed,
        seed: 3,
    };
    let t0 = Instant::now();
    let stats = run_open_loop(&client, &mix, &cfg);
    let wall = t0.elapsed();

    // Every scheduled arrival fired: offered load never bent to the
    // stall. (A closed-loop generator with 8 sessions would have sent
    // only ~80 requests in the window.)
    assert_eq!(stats.sent, 100, "open loop must dispatch every arrival");
    assert_eq!(stats.ok + stats.failed, 100, "every dispatch settles");
    assert_eq!(stats.failed, 0, "stalls are slow, not failures");
    // 100 req through 8 sessions × 100ms each ≈ 13 serial waves; the
    // run must have outlived the 1s schedule window by the backlog.
    assert!(
        wall >= Duration::from_millis(1200),
        "backlog must drain after the window ({wall:?})"
    );
    // Anti-coordinated-omission: tail latency includes queueing from
    // the *scheduled* arrival, so it must far exceed one stall.
    let p99 = stats.latency.p99();
    assert!(
        p99 >= Duration::from_millis(200),
        "p99 {p99:?} must charge queueing to the request, not hide it \
         (one stall is only 100ms — anything under ~2× means omission)"
    );
    assert_eq!(handler.answered.load(Ordering::Relaxed), 100);
    server.shutdown();
}

/// The schedule and the mix replay exactly under one seed, and differ
/// across seeds (Poisson).
#[test]
fn schedule_and_mix_replay_under_seed() {
    let a: Vec<Duration> = Schedule::new(777.0, Arrival::Poisson, 9).take(500).collect();
    let b: Vec<Duration> = Schedule::new(777.0, Arrival::Poisson, 9).take(500).collect();
    assert_eq!(a, b);

    let mix = WorkloadMix::new(300, 1.2, 8, default_classes(), 21);
    let draw = |seed: u64| -> Vec<(usize, usize)> {
        let mut rng = Rng::seeded(seed);
        (0..500)
            .map(|_| {
                let r = mix.sample(&mut rng);
                (r.user, r.class)
            })
            .collect()
    };
    assert_eq!(draw(5), draw(5), "same workload RNG seed → same traffic");
    assert_ne!(draw(5), draw(6), "different seed → different traffic");
}

/// User-key frequencies match the Zipf law the mix claims to draw from.
#[test]
fn user_draw_matches_zipf_pmf() {
    let users = 200;
    let mix = WorkloadMix::new(users, 1.3, 4, default_classes(), 2);
    let mut rng = Rng::seeded(17);
    let draws = 400_000usize;
    let mut counts = vec![0u64; users];
    for _ in 0..draws {
        counts[mix.sample(&mut rng).user] += 1;
    }
    // Compare observed frequency to the pmf on the head (the tail of a
    // Zipf needs astronomically many draws for tight bounds).
    for rank in 0..20 {
        let want = mix.zipf().pmf(rank);
        let got = counts[rank] as f64 / draws as f64;
        assert!(
            (got - want).abs() < want * 0.1 + 1e-4,
            "rank {rank}: observed {got:.5} vs pmf {want:.5}"
        );
    }
    // Monotone-ish head: rank 0 strictly dominates rank 5+.
    assert!(counts[0] > counts[5]);
    assert!(counts[0] > counts[19]);
}

/// ACCEPTANCE: a healthy open-loop run against the self-spawned
/// cluster — mixed kinds, tight deadlines, mid-run epoch publishes —
/// settles every request with zero hard failures, and the sweep's
/// knee detector sees an un-saturated system keep up.
#[test]
fn harness_healthy_run_zero_failures_with_publishes() {
    let h = ClusterHarness::spawn(&HarnessConfig {
        n: 1024,
        dim: 16,
        shards: 2,
        replicas: 1,
        seed: 5,
        service_workers: 2,
        ..HarnessConfig::default()
    })
    .unwrap();
    let client =
        Arc::new(PartitionClient::connect(h.addr.clone(), ClientConfig::for_sessions(16)).unwrap());
    let mix = Arc::new(WorkloadMix::new(500, 1.1, 16, default_classes(), 5));
    let cfg = RunConfig {
        rate_hz: 150.0,
        duration: Duration::from_millis(1500),
        sessions: 16,
        arrival: Arrival::Poisson,
        seed: 5,
    };
    // Writer: two publish waves mid-run (add then remove → size-stable).
    let stats = std::thread::scope(|s| {
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(400));
            h.publish_add(32, 1).expect("mid-run add publish");
            std::thread::sleep(Duration::from_millis(400));
            h.publish_remove_tail(32).expect("mid-run remove publish");
        });
        run_open_loop(&client, &mix, &cfg)
    });
    assert!(stats.sent >= 150, "≈225 arrivals expected, got {}", stats.sent);
    assert_eq!(
        stats.failed, 0,
        "healthy run must have zero hard failures (ok={} shed={} rejected={})",
        stats.ok, stats.shed, stats.rejected
    );
    assert!(
        stats.ok as f64 >= stats.sent as f64 * 0.9,
        "healthy run below the knee should answer ~everything (ok={} of {})",
        stats.ok,
        stats.sent
    );
    let point = zest::loadgen::to_point(&stats, &Default::default());
    assert_eq!(find_knee(std::slice::from_ref(&point)), None, "not saturated");
    drop(client);
    h.shutdown();
}

/// ACCEPTANCE (hedged reads): delay one replica's link well past the
/// hedge delay; hedge-safe `TopK` traffic must complete fast via the
/// duplicate on the healthy replica, tick `shard_hedges`, and land in
/// the per-shard `shard_stats[..].hedges` table.
#[test]
fn hedged_topk_ticks_counters_and_answers() {
    let h = ClusterHarness::spawn(&HarnessConfig {
        n: 512,
        dim: 16,
        shards: 2,
        replicas: 2,
        proxied: true,
        seed: 11,
        service_workers: 2,
        hedge_delay: Some(Duration::from_millis(10)),
        ..HarnessConfig::default()
    })
    .unwrap();
    // Replica 0 of every shard answers 200ms late — 20× the hedge
    // delay, far under the transport timeout, so without hedging every
    // read routed there would eat the delay.
    for p in &h.proxies {
        p.set_mode(FaultMode::Delay(200));
    }
    let client =
        Arc::new(PartitionClient::connect(h.addr.clone(), ClientConfig::default()).unwrap());
    let mut rng = Rng::seeded(23);
    for _ in 0..12 {
        let spec = EstimateSpec::new(rng.unit_vec(16))
            .kind(EstimatorKind::Nmimps)
            .k(8);
        let resp = client.estimate(spec).expect("hedged top-k read answers");
        assert!(resp.z.is_finite() && resp.z > 0.0);
    }
    let blob = client.get_metrics().unwrap();
    assert!(
        blob.counter("shard_hedges") > 0,
        "delayed replica must have fired hedges (blob: {:?})",
        blob.counters
    );
    // The per-shard table sees them too (sink mirroring).
    let snap = h.svc.metrics().shard_stats;
    let hedges: u64 = snap.iter().map(|s| s.hedges).sum();
    assert!(hedges > 0, "shard_stats must mirror hedge ticks: {snap:?}");
    for p in &h.proxies {
        p.restore();
    }

    // ACCEPTANCE (exposition): the same health counters scrape through
    // the Prometheus text endpoint (`zest-server --metrics-listen`'s
    // source shape: service blob merged with the backend's cluster
    // counters). Tick one deadline shed first so the counter is live.
    let err = h
        .svc
        .estimate(
            EstimateSpec::new(rng.unit_vec(16))
                .deadline(Instant::now() - Duration::from_millis(1)),
        )
        .unwrap_err();
    assert_eq!(err, zest::coordinator::SubmitError::DeadlineExceeded);
    let source: std::sync::Arc<dyn Fn() -> zest::obs::MetricsBlob + Send + Sync> = {
        let svc = Arc::clone(&h.svc);
        Arc::new(move || {
            let mut blob = svc.metrics_handle().blob();
            if let Some(workers) = svc.backend().metrics() {
                blob.merge(&workers);
            }
            blob
        })
    };
    let mut http = zest::obs::MetricsHttpServer::serve(&loopback(), source).unwrap();
    let body = {
        use std::io::{Read as _, Write as _};
        let mut conn = zest::net::Stream::connect(http.addr()).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        out
    };
    assert!(body.starts_with("HTTP/1.0 200"), "{body}");
    let sample = |name: &str| -> u64 {
        body.lines()
            .find_map(|l| l.strip_prefix(&format!("{name} ")))
            .unwrap_or_else(|| panic!("no {name} sample in:\n{body}"))
            .parse()
            .unwrap()
    };
    assert!(sample("zest_shard_hedges") > 0, "hedges must export");
    assert_eq!(sample("zest_deadline_shed"), 1, "the shed we provoked");
    // Present (zero is fine — nothing failed over or backpressured).
    assert!(body.contains("# TYPE zest_shard_failovers counter"));
    assert!(body.contains("# TYPE zest_shed counter"));
    http.shutdown();

    drop(client);
    h.shutdown();
}
