//! End-to-end suite for the network serving layer:
//!
//! * **Acceptance**: `Exact` served through `RemoteShardIndex` /
//!   `RemoteCluster` over UDS is **bit-identical** to the in-process
//!   `ShardedStore` answer for S ∈ {1, 2, 4} (4-aligned worker splits —
//!   see `net::remote` module docs for the alignment contract).
//! * **Acceptance**: remote MINCE and FMBE match the in-process
//!   estimators on identical seeds for S ∈ {1, 2, 4} (MINCE to float
//!   tolerance — identical draws, differently-chunked scoring passes;
//!   FMBE bit-identical at S = 1, summation-order tolerance above).
//! * **Acceptance**: `RemoteCluster::publish` issues all worker
//!   prepares concurrently — a slow-worker handler proves the prepare
//!   windows overlap and publish latency is max-not-sum.
//! * **Acceptance**: a malformed / truncated frame closes the
//!   connection with an error response; the server keeps serving.
//! * **Acceptance** (wire v3): ≥256 concurrent connections served by a
//!   reactor pool of ≤4 threads; ≥8 overlapped RPCs on **one** socket
//!   completing out of submission order; a rogue response with a
//!   mismatched request id is a typed client error, not a panic.
//! * **Acceptance** (front door): a 64-way identical-request herd costs
//!   exactly one backend group call (`coalesced == 63`, bit-identical
//!   answers); epoch-keyed cache hits are bit-identical and an
//!   `add_categories` publish invalidates them, for S ∈ {1, 2, 4}.
//! * **Acceptance** (observability): a traced request through the full
//!   cluster stack records frontdoor → queue → batch → per-worker RPC
//!   spans with worker-side exec attributed per shard (wire-v5 timing
//!   annex), dumps as Chrome trace JSON, and `GetMetrics` merges the
//!   coordinator's and every worker's snapshots into one blob.
//! * `PartitionClient` ↔ `ServiceHandler` mirrors the in-process
//!   service (same answers, typed error mapping, net metrics).
//! * Two-phase epoch publish across workers: all-or-nothing prepare,
//!   lockstep commit, and correct answers after add/remove.
//! * The real `zest-server` + `zest-shard-worker` binaries over UDS.
#![cfg(unix)]

use std::io::Write as _;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use zest::coordinator::{
    ClusterBackend, EstimateSpec, PartitionService, Precision, Router, ServiceConfig,
    ServiceMetrics, SubmitError,
};
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::fmbe::{Fmbe, FmbeConfig};
use zest::estimators::{
    exact::Exact, mimps::Mimps, mince::Mince, EstimateContext, Estimator, EstimatorKind,
};
use zest::mips::brute::BruteIndex;
use zest::net::client::{ClientConfig, ClientError, PartitionClient};
use zest::net::remote::{aligned_split, ClusterHandler, RemoteCluster, RemoteShard};
use zest::net::server::{Handler, Server, ServerConfig, ServiceHandler};
use zest::net::shard::ShardWorker;
use zest::net::{wire, Addr};
use zest::store::{exp_sum_view, ShardedStore, SnapshotHandle, StoreView};
use zest::util::rng::Rng;

static SOCKET_SEQ: AtomicUsize = AtomicUsize::new(0);

fn sock_addr(tag: &str) -> Addr {
    let seq = SOCKET_SEQ.fetch_add(1, Ordering::SeqCst);
    Addr::Unix(std::env::temp_dir().join(format!(
        "zest-e2e-{}-{tag}-{seq}.sock",
        std::process::id()
    )))
}

fn store(n: usize, d: usize) -> EmbeddingStore {
    generate(&SynthConfig {
        n,
        d,
        ..SynthConfig::tiny()
    })
}

/// Start one in-process shard-worker server per 4-aligned block. Each
/// worker shares its metrics sink with its server, like the real
/// `zest-shard-worker` binary, so `GetMetrics` scrapes see the wire
/// counters.
fn spawn_workers(s: &EmbeddingStore, count: usize, tag: &str) -> (Vec<Server>, Vec<Addr>) {
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for (i, block) in aligned_split(s, count).into_iter().enumerate() {
        let addr = sock_addr(&format!("{tag}{i}"));
        let metrics = Arc::new(ServiceMetrics::new());
        let server = Server::serve(
            &addr,
            Arc::new(ShardWorker::new(block).with_metrics(metrics.clone())),
            ServerConfig::default(),
            metrics,
        )
        .unwrap();
        addrs.push(server.local_addr().clone());
        servers.push(server);
    }
    (servers, addrs)
}

/// ACCEPTANCE: remote `Exact` is bit-identical to the in-process
/// sharded answer for S ∈ {1, 2, 4}, single-query (gemv chain) and
/// batched (gemm chain).
#[test]
fn remote_exact_bit_identical_over_uds() {
    let s = store(600, 16);
    let qs: Vec<Vec<f32>> = (0..4).map(|i| s.row(i * 140 + 3).to_vec()).collect();
    for count in [1usize, 2, 4] {
        let (servers, addrs) = spawn_workers(&s, count, "exact");
        let cluster = RemoteCluster::connect(&addrs, ClientConfig::default()).unwrap();
        assert_eq!(cluster.len(), 600);
        assert_eq!(cluster.dim(), 16);
        assert_eq!(cluster.num_shards(), count);

        // Single-query chain vs the in-process sharded streaming kernel.
        let sharded = ShardedStore::split(&s, count);
        for q in &qs {
            let want = exp_sum_view(&sharded, q);
            let got = cluster.exp_sum(q).unwrap();
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "S={count}: remote {got} vs in-process {want}"
            );
        }

        // Batched chain vs the in-process batched Exact estimator.
        let mono = BruteIndex::new(&s);
        let want: Vec<f64> = {
            let mut rng = Rng::seeded(0);
            let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
            Exact.estimate_batch(&mut ctx, &qs)
        };
        let got = cluster.exp_sum_batch(&qs).unwrap();
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "S={count} q{qi}: {g} vs {w}");
        }

        // Release pooled connections before joining the servers.
        drop(cluster);
        for server in servers {
            server.shutdown();
        }
    }
}

/// ACCEPTANCE: remote MINCE and FMBE — the two estimators PR 3 could
/// not serve from a remote shard set — match the in-process estimators
/// on identical seeds for S ∈ {1, 2, 4}.
///
/// * MINCE consumes the RNG in exactly the in-process sequence (head
///   from the scatter, noise via `tail::sample_tail_ids`, scored
///   remotely) so the draws are identical; answers agree to float
///   tolerance because head/noise scores come from differently-chunked
///   scoring passes.
/// * FMBE is fitted per worker (`FitFmbe`) and the λ̃ vectors summed
///   cluster-side: bit-identical to a monolithic fit at S = 1, equal to
///   f64 summation-order tolerance for S > 1.
#[test]
fn remote_mince_and_fmbe_match_in_process() {
    let s = store(600, 16);
    let qs: Vec<Vec<f32>> = (0..3).map(|i| s.row(i * 190 + 7).to_vec()).collect();
    let (k, l, seed) = (40usize, 60usize, 123u64);
    let fmbe_cfg = FmbeConfig {
        p_features: 400,
        seed: 9,
        ..Default::default()
    };

    // In-process references.
    let mono = BruteIndex::new(&s);
    let want_mince: Vec<f64> = {
        let mut rng = Rng::seeded(seed);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Mince::new(k, l).estimate_batch(&mut ctx, &qs)
    };
    let want_fmbe: Vec<f64> = Fmbe::fit(&s, fmbe_cfg.clone()).estimate_queries(&qs);

    for count in [1usize, 2, 4] {
        let (servers, addrs) = spawn_workers(&s, count, "mincefmbe");
        let cluster = RemoteCluster::connect(&addrs, ClientConfig::default())
            .unwrap()
            .with_fmbe_config(fmbe_cfg.clone());

        let mut rng = Rng::seeded(seed);
        let mince = cluster
            .estimate_batch(EstimatorKind::Mince, k, l, Precision::BitExact, &qs, &mut rng, None)
            .unwrap();
        assert_eq!(mince.epoch, 0);
        for (qi, (got, want)) in mince.zs.iter().zip(&want_mince).enumerate() {
            let rel = ((got - want) / want).abs();
            assert!(
                rel < 2e-4,
                "S={count} q{qi}: remote MINCE {got} vs in-process {want} (rel {rel})"
            );
        }

        let mut rng = Rng::seeded(0); // FMBE draws nothing from it
        let fmbe = cluster
            .estimate_batch(EstimatorKind::Fmbe, 0, 0, Precision::BitExact, &qs, &mut rng, None)
            .unwrap();
        for (qi, (got, want)) in fmbe.zs.iter().zip(&want_fmbe).enumerate() {
            if count == 1 {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "S=1 q{qi}: remote FMBE {got} vs in-process {want}"
                );
            } else {
                let rel = ((got - want) / want).abs();
                assert!(
                    rel < 1e-5,
                    "S={count} q{qi}: remote FMBE {got} vs in-process {want} (rel {rel})"
                );
            }
        }
        // Second call answers from the epoch-tagged cached fit (same bits).
        let again = cluster
            .estimate_batch(
                EstimatorKind::Fmbe,
                0,
                0,
                Precision::BitExact,
                &qs,
                &mut Rng::seeded(0),
                None,
            )
            .unwrap();
        for (a, b) in again.zs.iter().zip(&fmbe.zs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        drop(cluster); // release pooled connections before joining
        for server in servers {
            server.shutdown();
        }
    }
}

/// ACCEPTANCE: the two-phase publish fans out: all worker prepares are
/// in flight **concurrently**. Every worker's prepare sleeps `DELAY`;
/// per-worker timestamps recorded by the test handler must pairwise
/// overlap, and the whole publish must cost ~max, not Σ, of the worker
/// delays.
#[test]
fn publish_prepares_overlap_across_workers() {
    const WORKERS: usize = 3;
    const DELAY: std::time::Duration = std::time::Duration::from_millis(300);

    /// Wraps a [`ShardWorker`], sleeping in every `Prepare*` and logging
    /// `(worker, start, end)` of the delayed handling window.
    struct SlowPrepare {
        inner: ShardWorker,
        id: usize,
        log: Arc<std::sync::Mutex<Vec<(usize, std::time::Instant, std::time::Instant)>>>,
    }

    impl Handler for SlowPrepare {
        fn handle(&self, req: wire::Request) -> wire::Response {
            let is_prepare = matches!(
                req,
                wire::Request::PrepareAdd { .. } | wire::Request::PrepareRemove { .. }
            );
            if !is_prepare {
                return self.inner.handle(req);
            }
            let start = std::time::Instant::now();
            std::thread::sleep(DELAY);
            let resp = self.inner.handle(req);
            self.log
                .lock()
                .unwrap()
                .push((self.id, start, std::time::Instant::now()));
            resp
        }
    }

    let s = store(240, 8);
    let log = Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for (id, block) in aligned_split(&s, WORKERS).into_iter().enumerate() {
        let addr = sock_addr(&format!("overlap{id}"));
        let server = Server::serve(
            &addr,
            Arc::new(SlowPrepare {
                inner: ShardWorker::new(block),
                id,
                log: log.clone(),
            }),
            ServerConfig::default(),
            Arc::new(ServiceMetrics::new()),
        )
        .unwrap();
        addrs.push(server.local_addr().clone());
        servers.push(server);
    }
    let cluster = RemoteCluster::connect(&addrs, ClientConfig::default()).unwrap();

    let added = generate(&SynthConfig {
        n: 8,
        d: 8,
        seed: 3,
        ..SynthConfig::tiny()
    });
    let t0 = std::time::Instant::now();
    assert_eq!(cluster.add_categories(&added).unwrap(), 1);
    let elapsed = t0.elapsed();

    let entries = log.lock().unwrap().clone();
    assert_eq!(entries.len(), WORKERS, "{entries:?}");
    // Latency is max-over-workers: a sequential prepare loop would cost
    // ≥ 3 × DELAY (900 ms) before commits even start.
    assert!(
        elapsed < DELAY * 5 / 2,
        "publish took {elapsed:?}; sequential would be ≥ {:?}",
        DELAY * WORKERS as u32
    );
    // Every pair of prepare windows overlaps: the last one to start
    // began before the first one ended.
    let latest_start = entries.iter().map(|e| e.1).max().unwrap();
    let earliest_end = entries.iter().map(|e| e.2).min().unwrap();
    assert!(
        latest_start < earliest_end,
        "prepare windows did not overlap: {entries:?}"
    );

    drop(cluster);
    for server in servers {
        server.shutdown();
    }
}

/// ACCEPTANCE: garbage and truncated frames get an error response and a
/// closed connection — and the server keeps serving afterwards.
#[test]
fn malformed_frames_close_with_error_not_panic() {
    let s = store(40, 8);
    let addr = sock_addr("malformed");
    let metrics = Arc::new(ServiceMetrics::new());
    let server = Server::serve(
        &addr,
        Arc::new(ShardWorker::new(s)),
        ServerConfig::default(),
        metrics.clone(),
    )
    .unwrap();
    let Addr::Unix(path) = server.local_addr().clone() else {
        panic!("expected a unix addr")
    };

    // Garbage bytes: the server answers BadRequest and closes.
    {
        let mut conn = UnixStream::connect(&path).unwrap();
        conn.write_all(b"GARBAGEGARBAGEGARBAGE").unwrap();
        conn.flush().unwrap();
        let (id, resp) = wire::read_response(&mut conn).unwrap().unwrap();
        assert_eq!(id, 0, "unframeable input gets a connection-level error");
        assert!(
            matches!(
                resp,
                wire::Response::Error {
                    code: wire::ErrorCode::BadRequest,
                    ..
                }
            ),
            "{resp:?}"
        );
        assert_eq!(
            wire::read_response(&mut conn).unwrap(),
            None,
            "connection must be closed after a malformed frame"
        );
    }

    // Truncated frame: a valid header whose payload never arrives.
    {
        let mut conn = UnixStream::connect(&path).unwrap();
        let mut header = Vec::new();
        header.extend_from_slice(&wire::MAGIC);
        header.extend_from_slice(&wire::VERSION.to_le_bytes());
        header.extend_from_slice(&100u32.to_le_bytes());
        header.extend_from_slice(&7u64.to_le_bytes()); // request id
        header.extend_from_slice(&[1, 2, 3]); // 3 of the promised 100 bytes
        conn.write_all(&header).unwrap();
        conn.shutdown(std::net::Shutdown::Write).unwrap();
        let (id, resp) = wire::read_response(&mut conn).unwrap().unwrap();
        assert_eq!(id, 0, "a truncated frame's id cannot be trusted");
        assert!(
            matches!(
                resp,
                wire::Response::Error {
                    code: wire::ErrorCode::BadRequest,
                    ..
                }
            ),
            "{resp:?}"
        );
    }

    // The server survived both: a fresh connection still answers.
    {
        let mut conn = UnixStream::connect(&path).unwrap();
        wire::write_request(&mut conn, 5, &wire::Request::Manifest).unwrap();
        let (id, resp) = wire::read_response(&mut conn).unwrap().unwrap();
        assert_eq!(id, 5, "the response echoes the request id");
        assert_eq!(
            resp,
            wire::Response::Manifest {
                len: 40,
                dim: 8,
                epoch: 0
            }
        );
    }
    assert!(metrics.snapshot().net.wire_errors >= 2);
    server.shutdown();
}

/// `PartitionClient` against a `ServiceHandler` front-end: same answers
/// as in-process submission, typed error mapping, shared net metrics.
#[test]
fn client_mirrors_in_process_service_over_uds() {
    let s = store(500, 16);
    let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&s, 2)));
    let svc = Arc::new(PartitionService::start_sharded(
        handle,
        Router::new(Default::default()),
        ServiceConfig {
            workers: 2,
            ..Default::default()
        },
        None,
    ));
    let addr = sock_addr("svc");
    let server = Server::serve(
        &addr,
        Arc::new(ServiceHandler::new(svc.clone())),
        ServerConfig::default(),
        svc.metrics_handle(),
    )
    .unwrap();
    let client = PartitionClient::connect(server.local_addr().clone(), ClientConfig::default())
        .unwrap();

    assert_eq!(client.manifest().unwrap(), (500, 16, 0));

    // Exact answers are deterministic → remote equals in-process bit
    // for bit (both are a batch-of-one through the same service).
    let q = s.row(123).to_vec();
    let local = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
    let remote = client.estimate(EstimateSpec::new(q.clone())).unwrap();
    assert_eq!(remote.z.to_bits(), local.z.to_bits());
    assert_eq!(remote.kind, EstimatorKind::Exact);
    assert_eq!(remote.epoch, 0);
    assert_eq!(remote.scorings, 500);

    // Batched mirror.
    let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 90 + 1).to_vec()).collect();
    let batch = client
        .estimate_batch(
            &EstimateSpec::template().kind(EstimatorKind::Mimps).k(50).l(50),
            qs.clone(),
        )
        .unwrap();
    assert_eq!(batch.len(), 5);
    for r in &batch {
        assert!(r.z.is_finite() && r.z > 0.0);
        assert_eq!(r.scorings, 100);
    }

    // Submit-time validation arrives as a typed remote error.
    let err = client.estimate(EstimateSpec::new(vec![0.0; 3])).unwrap_err();
    match err {
        ClientError::Remote { code, message } => {
            assert_eq!(code, wire::ErrorCode::DimMismatch);
            assert!(message.contains("dimensionality"), "{message}");
        }
        other => panic!("want Remote(DimMismatch), got {other}"),
    }

    // Net counters land in the service's own metrics sink.
    let m = svc.metrics();
    assert!(m.net.accepted >= 1, "{m}");
    assert!(m.net.frames_in >= 4, "{m}");
    assert!(m.net.frames_out >= 4, "{m}");
    drop(client); // release pooled connections before joining
    server.shutdown();
}

/// A partition server over remote shard workers (`ClusterHandler`):
/// the full scatter path — client → server → S workers — matches the
/// in-process estimators.
#[test]
fn cluster_served_estimates_match_in_process() {
    let s = store(600, 16);
    let (workers, addrs) = spawn_workers(&s, 2, "cluster");
    let fmbe_cfg = FmbeConfig {
        p_features: 300,
        seed: 4,
        ..Default::default()
    };
    let cluster = Arc::new(
        RemoteCluster::connect(&addrs, ClientConfig::default())
            .unwrap()
            .with_fmbe_config(fmbe_cfg.clone()),
    );
    let seed = 11u64;
    let addr = sock_addr("front");
    let server = Server::serve(
        &addr,
        Arc::new(ClusterHandler::new(cluster, seed)),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let client = PartitionClient::connect(server.local_addr().clone(), ClientConfig::default())
        .unwrap();
    assert_eq!(client.manifest().unwrap(), (600, 16, 0));

    // Exact: bit-identical to the in-process batched kernel.
    let q = s.row(42).to_vec();
    let remote = client.estimate(EstimateSpec::new(q.clone())).unwrap();
    let mono = BruteIndex::new(&s);
    let want: f64 = {
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Exact.estimate_batch(&mut ctx, std::slice::from_ref(&q))[0]
    };
    assert_eq!(remote.z.to_bits(), want.to_bits());

    // MIMPS: same global tail draw as in-process (the handler's seeded
    // RNG), scored remotely — agrees to float tolerance (head scores
    // come from differently-chunked GEMM passes).
    let remote_m = client
        .estimate(
            EstimateSpec::new(q.clone())
                .kind(EstimatorKind::Mimps)
                .k(60)
                .l(40),
        )
        .unwrap();
    let want_m: f64 = {
        // The handler seeds its RNG as seed ^ 0x5EED_0CEA and forks one
        // child per sampling request; this MIMPS call is the first.
        let mut parent = Rng::seeded(seed ^ 0x5EED_0CEA);
        let mut rng = parent.fork();
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Mimps::new(60, 40).estimate(&mut ctx, &q)
    };
    let rel = ((remote_m.z - want_m) / want_m).abs();
    assert!(rel < 1e-5, "remote MIMPS {} vs in-process {want_m}", remote_m.z);

    // FMBE: the full client → server → FitFmbe-fan-out path answers,
    // matching an in-process fit to λ̃ summation-order tolerance.
    let remote_f = client
        .estimate(EstimateSpec::new(q).kind(EstimatorKind::Fmbe))
        .unwrap();
    let want_f = Fmbe::fit(&s, fmbe_cfg).estimate_query(&s.row(42).to_vec());
    let rel = ((remote_f.z - want_f) / want_f).abs();
    assert!(
        rel < 1e-5,
        "remote FMBE {} vs in-process {want_f} (rel {rel})",
        remote_f.z
    );
    assert_eq!(remote_f.scorings, 300, "FMBE scorings mirror the router");

    drop(client); // release pooled connections before joining
    server.shutdown(); // dropping the handler releases its worker pools
    for w in workers {
        w.shutdown();
    }
}

/// Two-phase epoch publish across workers: lockstep commit on success,
/// all-or-nothing abort on prepare failure, correct answers throughout.
#[test]
fn two_phase_publish_across_workers() {
    let s = store(400, 8);
    let (workers, addrs) = spawn_workers(&s, 2, "publish");
    let cluster = RemoteCluster::connect(&addrs, ClientConfig::default()).unwrap();
    let q = s.row(9).to_vec();

    // Epoch 1: append rows (they join the last worker; 4-aligned
    // boundaries are preserved, so Exact stays bit-pinned).
    let added = generate(&SynthConfig {
        n: 24,
        d: 8,
        seed: 5,
        ..SynthConfig::tiny()
    });
    assert_eq!(cluster.add_categories(&added).unwrap(), 1);
    assert_eq!(cluster.len(), 424);
    assert_eq!(cluster.epoch(), 1);
    let mut combined = s.data().to_vec();
    combined.extend_from_slice(added.data());
    let grown = EmbeddingStore::from_data(424, 8, combined).unwrap();
    let want = exp_sum_view(&grown, &q);
    let got = cluster.exp_sum(&q).unwrap();
    assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");

    // Epoch 2: remove ids from both workers (ids compact downward;
    // 4-alignment breaks, so compare to tolerance).
    assert_eq!(cluster.remove_categories(&[0, 1, 250, 423]).unwrap(), 2);
    assert_eq!(cluster.len(), 420);
    let mut kept = Vec::new();
    for i in 0..424 {
        if ![0usize, 1, 250, 423].contains(&i) {
            kept.extend_from_slice(StoreView::row(&grown, i));
        }
    }
    let shrunk = EmbeddingStore::from_data(420, 8, kept).unwrap();
    let want = exp_sum_view(&shrunk, &q);
    let got = cluster.exp_sum(&q).unwrap();
    assert!(
        (got - want).abs() <= 1e-6 * want,
        "after remove: {got} vs {want}"
    );

    // The scatter index tracks the new layout.
    assert_eq!(cluster.index().len(), 420);

    // A removal that would empty worker 0 outright fails at prepare,
    // aborts everywhere, and leaves every epoch untouched.
    let first_len = {
        // worker 0's current row count = lens[0]
        cluster.index().shard_offset(1)
    };
    let all_of_first: Vec<usize> = (0..first_len).collect();
    assert!(cluster.remove_categories(&all_of_first).is_err());
    assert_eq!(cluster.epoch(), 2, "failed publish must not advance");
    assert_eq!(cluster.len(), 420);
    cluster.refresh().unwrap();
    assert_eq!(cluster.epoch(), 2, "workers stayed in lockstep at epoch 2");

    drop(cluster); // release pooled connections before joining
    for w in workers {
        w.shutdown();
    }
}

/// Connection limit: excess connections are answered `Busy` and closed;
/// freeing a slot restores service.
#[test]
fn connection_limit_sheds_with_busy() {
    let s = store(20, 8);
    let addr = sock_addr("limit");
    let metrics = Arc::new(ServiceMetrics::new());
    let server = Server::serve(
        &addr,
        Arc::new(ShardWorker::new(s)),
        ServerConfig {
            max_connections: 1,
            read_timeout: Some(std::time::Duration::from_secs(5)),
            ..Default::default()
        },
        metrics.clone(),
    )
    .unwrap();
    let Addr::Unix(path) = server.local_addr().clone() else {
        panic!()
    };

    // Fill the one slot (and prove it serves).
    let mut held = UnixStream::connect(&path).unwrap();
    wire::write_request(&mut held, 1, &wire::Request::Ping).unwrap();
    assert_eq!(
        wire::read_response(&mut held).unwrap(),
        Some((1, wire::Response::Pong))
    );

    // The next connection is turned away with ConnLimit (id 0: the
    // rejection answers the connection, not any request).
    let mut second = UnixStream::connect(&path).unwrap();
    let (id, resp) = wire::read_response(&mut second).unwrap().unwrap();
    assert_eq!(id, 0);
    assert!(
        matches!(
            resp,
            wire::Response::Error {
                code: wire::ErrorCode::ConnLimit,
                ..
            }
        ),
        "{resp:?}"
    );

    // Free the slot; within a moment a fresh connection serves again.
    drop(held);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let mut retry = UnixStream::connect(&path).unwrap();
        wire::write_request(&mut retry, 1, &wire::Request::Ping).unwrap();
        match wire::read_response(&mut retry).unwrap() {
            Some((1, wire::Response::Pong)) => break,
            _ if std::time::Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            other => panic!("slot never freed: {other:?}"),
        }
    }
    assert!(metrics.snapshot().net.rejected >= 1);
    server.shutdown();
}

/// The real binaries: two `zest-shard-worker` processes + one
/// `zest-server --workers` over UDS, driven by `PartitionClient`, with
/// the remote `Exact` answer bit-identical to in-process.
#[test]
fn spawned_binaries_serve_exact_bit_identical() {
    struct ChildGuard(std::process::Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    fn wait_ready(addr: &Addr) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            if let Ok(mut conn) = zest::net::Stream::connect(addr) {
                if wire::write_request(&mut conn, 1, &wire::Request::Ping).is_ok() {
                    if let Ok(Some((1, wire::Response::Pong))) = wire::read_response(&mut conn) {
                        return;
                    }
                }
            }
            assert!(
                std::time::Instant::now() < deadline,
                "server at {addr} never became ready"
            );
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }

    // The binaries generate the same deterministic synthetic set.
    let (n, d, seed) = (600usize, 16usize, 7u64);
    let s = generate(&SynthConfig {
        n,
        d,
        seed,
        ..Default::default()
    });

    let mut guards = Vec::new();
    let mut worker_addrs = Vec::new();
    for (i, range) in [(0usize, 300usize), (300, 600)].iter().enumerate() {
        let addr = sock_addr(&format!("bin-worker{i}"));
        let Addr::Unix(path) = &addr else { panic!() };
        let child = std::process::Command::new(env!("CARGO_BIN_EXE_zest-shard-worker"))
            .args([
                "--listen",
                &format!("unix://{}", path.display()),
                "--synth",
                &format!("{n},{d},{seed}"),
                "--range",
                &format!("{},{}", range.0, range.1),
            ])
            .stdout(std::process::Stdio::null())
            .spawn()
            .expect("spawn zest-shard-worker");
        guards.push(ChildGuard(child));
        worker_addrs.push(addr);
    }
    for addr in &worker_addrs {
        wait_ready(addr);
    }

    let front = sock_addr("bin-front");
    let Addr::Unix(front_path) = &front else {
        panic!()
    };
    let workers_flag = worker_addrs
        .iter()
        .map(|a| a.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let child = std::process::Command::new(env!("CARGO_BIN_EXE_zest-server"))
        .args([
            "--listen",
            &format!("unix://{}", front_path.display()),
            "--workers",
            &workers_flag,
        ])
        .stdout(std::process::Stdio::null())
        .spawn()
        .expect("spawn zest-server");
    guards.push(ChildGuard(child));
    wait_ready(&front);

    let client = PartitionClient::connect(front.clone(), ClientConfig::default()).unwrap();
    assert_eq!(client.manifest().unwrap(), (n, d, 0));

    let mono = BruteIndex::new(&s);
    for qi in [3usize, 250, 599] {
        let q = s.row(qi).to_vec();
        let remote = client.estimate(EstimateSpec::new(q.clone())).unwrap();
        let want: f64 = {
            let mut rng = Rng::seeded(0);
            let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
            Exact.estimate_batch(&mut ctx, std::slice::from_ref(&q))[0]
        };
        assert_eq!(
            remote.z.to_bits(),
            want.to_bits(),
            "q{qi}: remote {} vs in-process {want}",
            remote.z
        );
    }
}

/// ACCEPTANCE: the two-mode `Exact` over remote shards.
/// `Precision::BitExact` (the sequential chain) stays bit-identical to
/// the in-process batched kernel; `Precision::Pipelined` (the
/// `ExpSumPart` fan-out, reduced in worker order) matches it within a
/// tight relative-error bound — and bit-exactly at S = 1, where the
/// reduce adds a single partial to zero. Pinned for S ∈ {1, 2, 4}.
#[test]
fn pipelined_exact_matches_chain_within_ulp_bound() {
    let s = store(600, 16);
    let qs: Vec<Vec<f32>> = (0..4).map(|i| s.row(i * 140 + 11).to_vec()).collect();
    let mono = BruteIndex::new(&s);
    let want: Vec<f64> = {
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Exact.estimate_batch(&mut ctx, &qs)
    };
    for count in [1usize, 2, 4] {
        let (servers, addrs) = spawn_workers(&s, count, "pipelined");
        let cluster = RemoteCluster::connect(&addrs, ClientConfig::default()).unwrap();

        let chained = cluster.exp_sum_batch(&qs).unwrap();
        let pipelined = cluster.exp_sum_parts(&qs).unwrap();
        for (qi, ((c, p), w)) in chained.iter().zip(&pipelined).zip(&want).enumerate() {
            assert_eq!(
                c.to_bits(),
                w.to_bits(),
                "S={count} q{qi}: chained {c} vs in-process {w}"
            );
            if count == 1 {
                assert_eq!(
                    p.to_bits(),
                    w.to_bits(),
                    "S=1 q{qi}: pipelined must equal the chain bit for bit"
                );
            } else {
                let rel = ((p - w) / w).abs();
                assert!(
                    rel < 1e-12,
                    "S={count} q{qi}: pipelined {p} vs chained {w} (rel {rel})"
                );
            }
        }

        // The same two modes through the cluster's estimator entry point.
        let mut rng = Rng::seeded(0);
        let bit = cluster
            .estimate_batch(
                EstimatorKind::Exact,
                0,
                0,
                Precision::BitExact,
                &qs,
                &mut rng,
                None,
            )
            .unwrap();
        let pipe = cluster
            .estimate_batch(
                EstimatorKind::Exact,
                0,
                0,
                Precision::Pipelined,
                &qs,
                &mut rng,
                None,
            )
            .unwrap();
        for ((b, p), w) in bit.zs.iter().zip(&pipe.zs).zip(&want) {
            assert_eq!(b.to_bits(), w.to_bits());
            let rel = ((p - w) / w).abs();
            assert!(rel < 1e-12, "pipelined {p} vs {w} (rel {rel})");
        }

        drop(cluster);
        for server in servers {
            server.shutdown();
        }
    }
}

/// ACCEPTANCE: `PartitionService::start_with_backend(ClusterBackend::…)`
/// serves estimate/estimate_batch **through the dynamic batcher** with
/// metrics populated — the batching/backpressure/metrics front-end over
/// a remote cluster for the first time. `Precision::BitExact` answers
/// stay bit-identical to in-process `Exact` for S ∈ {1, 2, 4};
/// `Precision::Pipelined` passes the documented relative-error bound.
#[test]
fn cluster_backend_serves_through_batcher_with_metrics() {
    let s = store(600, 16);
    let qs: Vec<Vec<f32>> = (0..6).map(|i| s.row(i * 90 + 5).to_vec()).collect();
    let mono = BruteIndex::new(&s);
    let want: Vec<f64> = {
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &mono, &mut rng);
        Exact.estimate_batch(&mut ctx, &qs)
    };
    for count in [1usize, 2, 4] {
        let (servers, addrs) = spawn_workers(&s, count, &format!("svcback{count}"));
        let svc = PartitionService::start_with_backend(
            ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
            ServiceConfig {
                workers: 2,
                ..Default::default()
            },
        );
        assert_eq!(svc.dim(), 16);
        assert_eq!(svc.serving_info(), (600, 0));

        // estimate: both precision modes, one request each.
        let r_bit = svc.estimate(EstimateSpec::new(qs[0].clone())).unwrap();
        assert_eq!(
            r_bit.z.to_bits(),
            want[0].to_bits(),
            "S={count}: batched BitExact over ClusterBackend vs in-process"
        );
        assert_eq!(r_bit.scorings, 600);
        assert_eq!(r_bit.epoch, 0);
        let r_pipe = svc
            .estimate(EstimateSpec::new(qs[0].clone()).precision(Precision::Pipelined))
            .unwrap();
        let rel = ((r_pipe.z - want[0]) / want[0]).abs();
        assert!(rel < 1e-12, "pipelined {} vs {} (rel {rel})", r_pipe.z, want[0]);

        // estimate_batch: a submitted block coalesces through the
        // batcher into shared estimate_batch groups.
        let rxs: Vec<_> = qs
            .iter()
            .map(|q| svc.submit(EstimateSpec::new(q.clone())).unwrap())
            .collect();
        for (rx, w) in rxs.into_iter().zip(&want) {
            assert_eq!(rx.recv().unwrap().z.to_bits(), w.to_bits());
        }

        // A sampler scatters through the same backend.
        let rm = svc
            .estimate(
                EstimateSpec::new(qs[1].clone())
                    .kind(EstimatorKind::Mimps)
                    .k(50)
                    .l(50),
            )
            .unwrap();
        assert!(rm.z.is_finite() && rm.z > 0.0);
        assert_eq!(rm.scorings, 100);

        let m = svc.metrics();
        assert_eq!(m.completed, 9, "S={count}: {m}");
        assert!(m.batches >= 1);
        assert!(m.batch_throughput_rps > 0.0);
        assert_eq!(m.backend_errors, 0);
        assert_eq!(
            m.shard_stats.len(),
            count,
            "per-worker metrics populated: {m}"
        );
        assert!(m.shard_stats.iter().all(|st| st.batches >= 1));

        svc.shutdown(); // drops the backend → releases worker pools
        for server in servers {
            server.shutdown();
        }
    }
}

/// Batcher deadline-shed and backpressure, driven through
/// `start_with_backend` with a `ClusterBackend`: a deadline that
/// expires while queued is shed at drain time (typed error + metric), a
/// full queue under `Shed` rejects with `Overloaded`.
#[test]
fn cluster_backend_deadline_shed_and_backpressure() {
    /// Wraps a [`ShardWorker`], sleeping on every exp-sum op so batches
    /// are slow enough to fill the queue deterministically.
    struct SlowScore {
        inner: ShardWorker,
        delay: std::time::Duration,
    }

    impl Handler for SlowScore {
        fn handle(&self, req: wire::Request) -> wire::Response {
            if matches!(
                req,
                wire::Request::ExpSumChain { .. }
                    | wire::Request::ExpSumChainBatch { .. }
                    | wire::Request::ExpSumPart { .. }
            ) {
                std::thread::sleep(self.delay);
            }
            self.inner.handle(req)
        }
    }

    let s = store(160, 8);
    let addr = sock_addr("slowworker");
    let server = Server::serve(
        &addr,
        Arc::new(SlowScore {
            inner: ShardWorker::new(s.clone()),
            delay: std::time::Duration::from_millis(20),
        }),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let addrs = vec![server.local_addr().clone()];

    // Deadline shedding: a long batcher wait guarantees the short
    // deadline expires while the request is queued, so the drain-time
    // sweep sheds it and the caller gets the typed error.
    let svc = PartitionService::start_with_backend(
        ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
        ServiceConfig {
            workers: 1,
            batcher: zest::coordinator::BatcherConfig {
                max_batch: 64,
                max_wait: std::time::Duration::from_millis(300),
            },
            ..Default::default()
        },
    );
    let q = s.row(0).to_vec();
    let err = svc
        .estimate(
            EstimateSpec::new(q.clone()).deadline_in(std::time::Duration::from_millis(50)),
        )
        .unwrap_err();
    assert_eq!(err, SubmitError::DeadlineExceeded);
    assert_eq!(svc.metrics().deadline_shed, 1);
    // An already-expired deadline is rejected at submit.
    let err = svc
        .estimate(
            EstimateSpec::new(q.clone())
                .deadline(std::time::Instant::now() - std::time::Duration::from_millis(1)),
        )
        .unwrap_err();
    assert_eq!(err, SubmitError::DeadlineExceeded);
    assert_eq!(svc.metrics().deadline_shed, 2);
    // Deadline-free requests still answer correctly afterwards.
    let ok = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
    assert!(ok.z.is_finite() && ok.z > 0.0);
    svc.shutdown();

    // Backpressure: tiny queue + slow remote batches → Shed policy
    // rejects with Overloaded and counts the shed load.
    let svc = PartitionService::start_with_backend(
        ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            backpressure: zest::coordinator::BackpressurePolicy::Shed,
            batcher: zest::coordinator::BatcherConfig {
                max_batch: 1,
                max_wait: std::time::Duration::from_millis(1),
            },
            ..Default::default()
        },
    );
    let mut rejected = 0usize;
    let mut receivers = Vec::new();
    for i in 0..200 {
        match svc.submit(EstimateSpec::new(s.row(i % s.len()).to_vec())) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitError::Overloaded) => rejected += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert!(rejected > 0, "flood over a slow cluster should shed load");
    for rx in receivers {
        let _ = rx.recv();
    }
    let m = svc.metrics();
    assert_eq!(m.shed as usize, rejected, "{m}");
    svc.shutdown();
    server.shutdown();
}

/// ACCEPTANCE (front door): a 64-way thundering herd of identical
/// requests against a slow cluster costs exactly **one** backend group
/// call — one leader executes, 63 followers coalesce onto its
/// completion slot — and every caller gets the bit-identical answer.
#[test]
fn identical_request_herd_coalesces_to_one_backend_call() {
    /// Wraps a [`ShardWorker`], counting and slowing every exp-sum op
    /// so the whole herd is in flight before the leader completes.
    struct SlowCountedScore {
        inner: ShardWorker,
        delay: std::time::Duration,
        calls: Arc<AtomicUsize>,
    }

    impl Handler for SlowCountedScore {
        fn handle(&self, req: wire::Request) -> wire::Response {
            if matches!(
                req,
                wire::Request::ExpSumChain { .. }
                    | wire::Request::ExpSumChainBatch { .. }
                    | wire::Request::ExpSumPart { .. }
            ) {
                self.calls.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(self.delay);
            }
            self.inner.handle(req)
        }
    }

    let s = store(160, 8);
    let calls = Arc::new(AtomicUsize::new(0));
    let addr = sock_addr("herdworker");
    let server = Server::serve(
        &addr,
        Arc::new(SlowCountedScore {
            inner: ShardWorker::new(s.clone()),
            // Long enough that every follower's submit lands while the
            // leader's flight is still executing, even on a loaded CI
            // machine.
            delay: std::time::Duration::from_millis(250),
            calls: calls.clone(),
        }),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let addrs = vec![server.local_addr().clone()];
    let svc = PartitionService::start_with_backend(
        ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
        ServiceConfig {
            workers: 1,
            ..Default::default()
        },
    );

    const HERD: usize = 64;
    let barrier = std::sync::Barrier::new(HERD);
    let q = s.row(7).to_vec();
    let answers: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..HERD)
            .map(|_| {
                let (svc, barrier, q) = (&svc, &barrier, &q);
                scope.spawn(move || {
                    barrier.wait();
                    svc.estimate(EstimateSpec::new(q.clone())).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let bits = answers[0].z.to_bits();
    assert!(answers[0].z.is_finite() && answers[0].z > 0.0);
    for r in &answers {
        assert_eq!(r.z.to_bits(), bits, "herd answers must be bit-identical");
        assert!(
            !r.served_from_cache,
            "in-flight coalescing is not a cache hit"
        );
    }
    assert_eq!(
        calls.load(Ordering::SeqCst),
        1,
        "the whole herd must cost one backend group call"
    );
    let m = svc.metrics();
    assert_eq!(m.coalesced, (HERD - 1) as u64, "{m}");
    assert_eq!(m.cache_misses, 1, "{m}");
    assert_eq!(m.completed, HERD as u64, "{m}");
    assert_eq!(m.backend_errors, 0, "{m}");

    // A straggler arriving after the flight completed is a cache hit —
    // still no new backend call.
    let late = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
    assert!(late.served_from_cache);
    assert_eq!(late.z.to_bits(), bits);
    assert_eq!(calls.load(Ordering::SeqCst), 1);
    assert_eq!(svc.metrics().cache_hits, 1);

    svc.shutdown();
    server.shutdown();
}

/// ACCEPTANCE (front door): within an epoch a repeated request is a
/// bit-identical cache hit; an `add_categories` publish through the
/// service invalidates the whole cached epoch in O(1), and the next
/// answer is fresh and bit-exact vs uncached in-process execution on
/// the grown set — for S ∈ {1, 2, 4} (4-aligned appends keep `Exact`
/// bit-pinned; see `net::remote` module docs).
#[test]
fn publish_invalidates_front_door_cache_across_cluster_sizes() {
    let s = store(600, 16);
    let q = s.row(11).to_vec();
    let added = generate(&SynthConfig {
        n: 24,
        d: 16,
        seed: 5,
        ..SynthConfig::tiny()
    });
    let mut combined = s.data().to_vec();
    combined.extend_from_slice(added.data());
    let grown = EmbeddingStore::from_data(624, 16, combined).unwrap();

    // Uncached in-process references for both epochs.
    let want = |set: &EmbeddingStore| -> f64 {
        let index = BruteIndex::new(set);
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(set, &index, &mut rng);
        Exact.estimate_batch(&mut ctx, &[q.clone()])[0]
    };
    let (want0, want1) = (want(&s), want(&grown));

    for count in [1usize, 2, 4] {
        let (servers, addrs) = spawn_workers(&s, count, &format!("inval{count}"));
        let svc = PartitionService::start_with_backend(
            ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
            ServiceConfig {
                workers: 2,
                ..Default::default()
            },
        );

        let r1 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(!r1.served_from_cache);
        assert_eq!(r1.z.to_bits(), want0.to_bits(), "S={count}");
        let hit = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(
            hit.served_from_cache,
            "S={count}: repeat within the epoch must hit"
        );
        assert_eq!(hit.z.to_bits(), want0.to_bits(), "S={count}");
        assert_eq!(hit.epoch, 0);

        // Publish through the service: the front door observes the new
        // epoch synchronously, not at the next executed batch.
        assert_eq!(svc.add_categories(added.clone()).unwrap(), 1);
        let r2 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(
            !r2.served_from_cache,
            "S={count}: publish must invalidate the cached epoch"
        );
        assert_eq!(r2.epoch, 1);
        assert_eq!(
            r2.z.to_bits(),
            want1.to_bits(),
            "S={count}: fresh answer on the grown set"
        );

        let m = svc.metrics();
        assert_eq!(m.cache_hits, 1, "{m}");
        assert_eq!(m.cache_misses, 2, "{m}");
        assert_eq!(m.cache_invalidations, 1, "{m}");

        svc.shutdown();
        for server in servers {
            server.shutdown();
        }
    }
}

/// `RemoteCluster::refresh` auto-heals a worker that missed a commit:
/// after a publish whose commit phase failed on one worker (simulated
/// outage), the cluster is out of lockstep and the publish reports the
/// error — then, once the worker is reachable again, a plain
/// `refresh()` detects the one-epoch lag, re-sends the recorded commit,
/// and restores lockstep without operator intervention.
#[test]
fn refresh_auto_heals_a_missed_commit() {
    use std::sync::atomic::AtomicBool;

    /// Wraps a [`ShardWorker`]; while `blocked`, every `Commit` answers
    /// an injected `Internal` error (the worker is "unreachable" for
    /// the commit phase but keeps its staged preparation).
    struct FlakyCommit {
        inner: ShardWorker,
        blocked: Arc<AtomicBool>,
    }

    impl Handler for FlakyCommit {
        fn handle(&self, req: wire::Request) -> wire::Response {
            if matches!(req, wire::Request::Commit { .. })
                && self.blocked.load(Ordering::SeqCst)
            {
                return wire::Response::Error {
                    code: wire::ErrorCode::Internal,
                    message: "injected outage".to_string(),
                };
            }
            self.inner.handle(req)
        }
    }

    let s = store(240, 8);
    let blocked = Arc::new(AtomicBool::new(false));
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for (i, block) in aligned_split(&s, 2).into_iter().enumerate() {
        let addr = sock_addr(&format!("heal{i}"));
        let handler: Arc<dyn Handler> = if i == 1 {
            Arc::new(FlakyCommit {
                inner: ShardWorker::new(block),
                blocked: blocked.clone(),
            })
        } else {
            Arc::new(ShardWorker::new(block))
        };
        let server = Server::serve(
            &addr,
            handler,
            ServerConfig::default(),
            Arc::new(ServiceMetrics::new()),
        )
        .unwrap();
        addrs.push(server.local_addr().clone());
        servers.push(server);
    }
    let cluster = RemoteCluster::connect(&addrs, ClientConfig::default()).unwrap();
    let q = s.row(3).to_vec();
    let before = cluster.exp_sum(&q).unwrap();

    // Publish with worker 1's commits failing: worker 0 commits epoch 1,
    // worker 1 stays at epoch 0 holding the staged preparation.
    blocked.store(true, Ordering::SeqCst);
    let added = generate(&SynthConfig {
        n: 8,
        d: 8,
        seed: 21,
        ..SynthConfig::tiny()
    });
    assert!(
        cluster.add_categories(&added).is_err(),
        "a failed commit phase must surface"
    );
    assert!(
        cluster.refresh().is_err(),
        "workers are out of lockstep while the outage lasts"
    );

    // The worker reconnects; a plain refresh heals the missed commit.
    blocked.store(false, Ordering::SeqCst);
    cluster.refresh().unwrap();
    assert_eq!(cluster.epoch(), 1, "lockstep restored at the target epoch");
    assert_eq!(cluster.len(), 248);
    // The healed cluster serves the grown category set (bit-identical:
    // the appended rows land on the last worker, boundaries unchanged).
    let mut combined = s.data().to_vec();
    combined.extend_from_slice(added.data());
    let grown = EmbeddingStore::from_data(248, 8, combined).unwrap();
    let want = exp_sum_view(&grown, &q);
    let got = cluster.exp_sum(&q).unwrap();
    assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
    assert!(got > before);

    // Healed state is sticky: another publish goes through cleanly.
    assert_eq!(cluster.remove_categories(&[0]).unwrap(), 2);
    assert_eq!(cluster.len(), 247);

    drop(cluster);
    for server in servers {
        server.shutdown();
    }
}

/// REGRESSION: a replica that misses **two consecutive commits** heals
/// from the coordinator's publish log. The pre-replica heal path kept
/// only the single most recent unresolved `(token, epoch)` — a worker
/// lagging by two epochs was unhealable short of an operator restart
/// with fresh data. The log-replay path must walk *every* missed
/// publish in order: replay the recorded prepare when the replica holds
/// no staging, then the commit, for each missed epoch.
#[test]
fn refresh_heals_two_missed_commits_from_the_publish_log() {
    let s = store(240, 8);
    let block = aligned_split(&s, 1).pop().unwrap();

    // One shard, two replicas (A direct, B about to die).
    let (mut servers, addrs) = {
        let mut servers = Vec::new();
        let mut addrs = Vec::new();
        for r in 0..2 {
            let addr = sock_addr(&format!("lag2-r{r}"));
            let server = Server::serve(
                &addr,
                Arc::new(ShardWorker::new(block.clone())),
                ServerConfig::default(),
                Arc::new(ServiceMetrics::new()),
            )
            .unwrap();
            addrs.push(server.local_addr().clone());
            servers.push(server);
        }
        (servers, addrs)
    };
    let cluster =
        RemoteCluster::connect_groups(&[addrs.clone()], ClientConfig::default()).unwrap();
    let q = s.row(3).to_vec();
    let before = cluster.exp_sum(&q).unwrap();

    // Kill replica B, then land TWO publishes through A alone.
    servers.pop().unwrap().shutdown();
    let add1 = generate(&SynthConfig {
        n: 8,
        d: 8,
        seed: 21,
        ..SynthConfig::tiny()
    });
    let add2 = generate(&SynthConfig {
        n: 4,
        d: 8,
        seed: 22,
        ..SynthConfig::tiny()
    });
    assert_eq!(cluster.add_categories(&add1).unwrap(), 1);
    assert_eq!(cluster.add_categories(&add2).unwrap(), 2);
    assert_eq!(cluster.len(), 252);
    assert_eq!(
        cluster.replica_status(),
        vec![vec![true, false]],
        "the dead replica must be marked unhealthy"
    );

    // Restart B on the same address with the ORIGINAL block: epoch 0,
    // two publishes behind — beyond what a lag-1 heal could fix.
    let server_b = Server::serve(
        &addrs[1],
        Arc::new(ShardWorker::new(block)),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    servers.push(server_b);

    // ONE refresh replays both logged publishes (prepare + commit each,
    // since the restarted worker holds no staging) and restores
    // lockstep and full health.
    cluster.refresh().unwrap();
    assert_eq!(cluster.epoch(), 2);
    assert_eq!(cluster.len(), 252);
    assert_eq!(cluster.replica_status(), vec![vec![true, true]]);

    // B really serves epoch 2 with the full grown set: ask it directly.
    let (_, (len, dim, epoch)) =
        RemoteShard::connect(addrs[1].clone(), ClientConfig::default()).unwrap();
    assert_eq!((len, dim, epoch), (252, 8, 2));

    // And the healed cluster's answers are bit-identical to the
    // monolithic grown reference (appends land on the last — only —
    // worker; boundaries unchanged).
    let mut combined = s.data().to_vec();
    combined.extend_from_slice(add1.data());
    combined.extend_from_slice(add2.data());
    let grown = EmbeddingStore::from_data(252, 8, combined).unwrap();
    let want = exp_sum_view(&grown, &q);
    let got = cluster.exp_sum(&q).unwrap();
    assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
    assert!(got > before);

    drop(cluster);
    for server in servers {
        server.shutdown();
    }
}

/// ACCEPTANCE (wire v3): a reactor pool of ≤4 threads serves ≥256
/// concurrent connections — far more sockets than threads, all open at
/// once, each answering a request.
#[test]
fn reactor_pool_serves_256_connections_on_4_threads() {
    const CONNS: usize = 256;
    let s = store(20, 8);
    let addr = sock_addr("manyconns");
    let server = Server::serve(
        &addr,
        Arc::new(ShardWorker::new(s)),
        ServerConfig {
            max_connections: CONNS + 8,
            read_timeout: Some(std::time::Duration::from_secs(30)),
            reactor_threads: 4,
            handler_threads: 8,
        },
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let Addr::Unix(path) = server.local_addr().clone() else {
        panic!()
    };

    // Open every connection before exchanging any frames: the whole set
    // is concurrently registered across the reactor pool.
    let mut conns: Vec<UnixStream> = (0..CONNS)
        .map(|_| UnixStream::connect(&path).unwrap())
        .collect();
    for (i, conn) in conns.iter_mut().enumerate() {
        let id = i as u64 + 1;
        wire::write_request(conn, id, &wire::Request::Manifest).unwrap();
    }
    for (i, conn) in conns.iter_mut().enumerate() {
        let id = i as u64 + 1;
        let got = wire::read_response(conn).unwrap();
        assert_eq!(
            got,
            Some((
                id,
                wire::Response::Manifest {
                    len: 20,
                    dim: 8,
                    epoch: 0
                }
            )),
            "connection {i}"
        );
    }
    drop(conns);
    server.shutdown();
}

/// ACCEPTANCE (wire v3): one socket carries ≥8 overlapped RPCs that
/// complete **out of submission order** — the first-submitted request
/// sleeps longest, so its response arrives last, and the total wall
/// clock is far below the sum of the handler delays.
#[test]
fn overlapped_rpcs_complete_out_of_submission_order() {
    const IN_FLIGHT: u64 = 8;
    const STEP_MS: u64 = 80;

    /// Sleeps `acc` milliseconds in `ExpSumChain`, then echoes `acc` —
    /// a handler whose latency the test controls per request.
    struct SleepEcho;
    impl Handler for SleepEcho {
        fn handle(&self, req: wire::Request) -> wire::Response {
            match req {
                wire::Request::ExpSumChain { acc, .. } => {
                    std::thread::sleep(std::time::Duration::from_millis(acc as u64));
                    wire::Response::ExpSums(vec![acc])
                }
                _ => wire::Response::Pong,
            }
        }
    }

    let addr = sock_addr("overlap-rpc");
    let server = Server::serve(
        &addr,
        Arc::new(SleepEcho),
        ServerConfig {
            handler_threads: IN_FLIGHT as usize,
            ..Default::default()
        },
        Arc::new(ServiceMetrics::new()),
    )
    .unwrap();
    let Addr::Unix(path) = server.local_addr().clone() else {
        panic!()
    };

    // One socket, 8 requests back to back: id i sleeps (9 - i) × STEP
    // ms, so submission order 1..8 should complete roughly reversed.
    let mut conn = UnixStream::connect(&path).unwrap();
    let delay_of = |id: u64| ((IN_FLIGHT + 1 - id) * STEP_MS) as f64;
    let t0 = std::time::Instant::now();
    for id in 1..=IN_FLIGHT {
        let req = wire::Request::ExpSumChain {
            acc: delay_of(id),
            query: vec![],
        };
        wire::write_request(&mut conn, id, &req).unwrap();
    }
    let mut arrivals = Vec::new();
    for _ in 0..IN_FLIGHT {
        let (id, resp) = wire::read_response(&mut conn).unwrap().unwrap();
        let wire::Response::ExpSums(v) = resp else {
            panic!("{resp:?}")
        };
        assert_eq!(v, vec![delay_of(id)], "response routed to the wrong id");
        arrivals.push(id);
    }
    let elapsed = t0.elapsed();

    let submitted: Vec<u64> = (1..=IN_FLIGHT).collect();
    let mut seen = arrivals.clone();
    seen.sort_unstable();
    assert_eq!(seen, submitted, "every RPC answered exactly once");
    assert_ne!(
        arrivals, submitted,
        "overlapped RPCs must complete out of submission order"
    );
    assert_ne!(
        arrivals.first(),
        Some(&1),
        "the longest-sleeping (first-submitted) RPC cannot finish first"
    );
    // Overlap: sum of delays is 8+7+…+1 = 36 steps; the max is 8 steps.
    let sum_ms = STEP_MS * (IN_FLIGHT * (IN_FLIGHT + 1) / 2);
    assert!(
        elapsed < std::time::Duration::from_millis(sum_ms / 2),
        "8 in-flight RPCs took {elapsed:?} — not overlapped (serial ≈ {sum_ms} ms)"
    );
    drop(conn);
    server.shutdown();
}

/// ACCEPTANCE (wire v3): a response tagged with the wrong request id is
/// survivable on both client paths — the pooled client surfaces a typed
/// protocol error, and the multiplexed pipeline ignores the unknown
/// frame and still routes the real answer. No panics either way.
#[test]
fn request_id_mismatch_is_an_error_not_a_panic() {
    use std::os::unix::net::UnixListener;
    use zest::net::remote::RemoteShard;

    // Rogue A: answers the first request with id+1 — the pooled
    // client's echo check must reject it.
    let addr = sock_addr("rogue-a");
    let Addr::Unix(path) = addr.clone() else {
        panic!()
    };
    let listener = UnixListener::bind(&path).unwrap();
    let rogue = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let (id, _req) = wire::read_request(&mut stream).unwrap().unwrap();
        wire::write_response(&mut stream, id + 1, &wire::Response::Pong).unwrap();
        // Hold the socket until the client gives up on it.
        let _ = wire::read_request(&mut stream);
    });
    let err = PartitionClient::connect(addr, ClientConfig::default()).unwrap_err();
    assert!(
        matches!(err, ClientError::Protocol(_)),
        "want Protocol error, got {err}"
    );
    rogue.join().unwrap();

    // Rogue B: prepends a frame with an id nobody asked for, then the
    // real answer — the multiplexed reader drops the stray and the
    // call completes.
    let addr = sock_addr("rogue-b");
    let Addr::Unix(path) = addr.clone() else {
        panic!()
    };
    let listener = UnixListener::bind(&path).unwrap();
    let rogue = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        while let Ok(Some((id, req))) = wire::read_request(&mut stream) {
            if !matches!(req, wire::Request::Manifest) {
                break;
            }
            wire::write_response(&mut stream, id + 999, &wire::Response::Pong).unwrap();
            let manifest = wire::Response::Manifest {
                len: 40,
                dim: 8,
                epoch: 0,
            };
            wire::write_response(&mut stream, id, &manifest).unwrap();
        }
    });
    let (shard, manifest) = RemoteShard::connect(addr, ClientConfig::default()).unwrap();
    assert_eq!(manifest, (40, 8, 0));
    assert_eq!(shard.manifest().unwrap(), (40, 8, 0));
    drop(shard);
    rogue.join().unwrap();
}

/// ACCEPTANCE (observability): a traced request served by the full
/// stack — `PartitionService` → `ClusterBackend` → two shard-worker
/// servers — records the complete span tree (frontdoor → queue →
/// batch → per-worker RPC, with worker-side exec attributed to each
/// shard through the wire-v5 timing annex), dumps as valid Chrome
/// trace-event JSON, and a `GetMetrics` scrape over the wire returns
/// the merged coordinator+worker blob whose per-stage percentiles come
/// from the new histograms.
#[test]
fn traced_cluster_request_spans_all_stages_with_per_worker_attribution() {
    let s = store(600, 16);
    let (workers, addrs) = spawn_workers(&s, 2, "traced");
    let svc = Arc::new(PartitionService::start_with_backend(
        ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
        ServiceConfig {
            workers: 1,
            trace_sample_rate: 1.0,
            ..Default::default()
        },
    ));

    // One traced request through the batcher and the remote exp-sum
    // chain (Exact / BitExact: sequential, one RPC per shard).
    let r = svc.estimate(EstimateSpec::new(s.row(42).to_vec())).unwrap();
    assert!(r.z.is_finite() && r.z > 0.0);

    // The sealed trace: coordinator stages on track 0, one rpc+worker
    // span pair per shard on tracks 1 and 2.
    let traces = svc.traces().completed();
    assert_eq!(traces.len(), 1, "rate-1.0 sampling must trace the request");
    let t = &traces[0];
    let names: Vec<&str> = t.events.iter().map(|e| e.name.as_str()).collect();
    for stage in ["frontdoor", "queue", "batch", "rpc", "worker"] {
        assert!(names.contains(&stage), "missing {stage} in {names:?}");
    }
    for shard in 0..2u64 {
        let track = 1 + shard;
        let rpc = t
            .events
            .iter()
            .find(|e| e.name == "rpc" && e.track == track)
            .unwrap_or_else(|| panic!("no rpc span on track {track}"));
        assert!(
            rpc.args.contains(&("shard".to_string(), shard.to_string())),
            "rpc span must name its shard: {:?}",
            rpc.args
        );
        let worker = t
            .events
            .iter()
            .find(|e| e.name == "worker" && e.track == track)
            .unwrap_or_else(|| panic!("no worker span on track {track}"));
        // The worker-side exec window (annex handle-lag + exec) nests
        // inside the client-observed rpc window: the server did its
        // work between this client's send and receive, and the
        // in-process workers share the test's monotonic clock.
        assert!(worker.start_ns >= rpc.start_ns);
        assert!(
            worker.start_ns + worker.dur_ns <= rpc.start_ns + rpc.dur_ns,
            "worker window [{}, +{}] outside rpc window [{}, +{}]",
            worker.start_ns,
            worker.dur_ns,
            rpc.start_ns,
            rpc.dur_ns
        );
    }
    assert!(t.wall_ns >= t.stage_ns("batch"));

    // The ring dumps as valid Chrome trace-event JSON.
    let dump = svc.traces().to_chrome_json();
    assert!(zest::util::json::Json::parse(&dump).is_ok(), "{dump}");

    // The trace fed the per-stage histograms.
    let m = svc.metrics();
    let stages: Vec<&str> = m.stage_stats.iter().map(|st| st.stage.as_str()).collect();
    for want in ["frontdoor", "rpc", "worker_exec"] {
        assert!(stages.contains(&want), "missing stage {want} in {stages:?}");
    }

    // GetMetrics over the wire: the scrape merges the coordinator's
    // blob with both workers' own snapshots.
    let addr = sock_addr("traced-front");
    let front = Server::serve(
        &addr,
        Arc::new(ServiceHandler::new(svc.clone())),
        ServerConfig::default(),
        svc.metrics_handle(),
    )
    .unwrap();
    let client =
        PartitionClient::connect(front.local_addr().clone(), ClientConfig::default()).unwrap();
    let blob = client.get_metrics().unwrap();
    assert!(blob.counter("completed") >= 1);
    let rpc_hist = blob.hist("rpc_ns").expect("rpc_ns histogram in the blob");
    assert_eq!(rpc_hist.count, 2, "one rpc sample per shard");
    assert!(rpc_hist.quantile(0.5) > 0 && rpc_hist.quantile(0.99) >= rpc_hist.quantile(0.5));
    assert_eq!(blob.hist("worker_exec_ns").unwrap().count, 2);
    // net_handle_ns samples only come from wire servers — seeing them
    // in the scrape proves the workers' blobs were merged in.
    assert!(
        blob.hist("net_handle_ns").unwrap().count >= 2,
        "worker handler timings must merge into the scrape"
    );

    drop(client);
    front.shutdown();
    drop(svc); // releases the backend → worker pools
    for w in workers {
        w.shutdown();
    }
}

/// ACCEPTANCE (soak): hundreds of *concurrently open* client
/// connections against the full self-spawned cluster — every one
/// issues real estimates in two waves with a metrics scrape between —
/// with **zero protocol errors** and **monotone frame counters**.
///
/// CI runs 256 connections; the full 10k-connection soak documented in
/// `docs/LOADGEN.md` is the same test scaled by environment:
///
/// ```bash
/// ulimit -n 32768
/// ZEST_SOAK_CONNS=10000 cargo test --release --test net_e2e many_connection_soak
/// ```
#[test]
fn many_connection_soak_zero_protocol_errors_monotone_frames() {
    use zest::loadgen::{ClusterHarness, HarnessConfig};

    let conns: usize = std::env::var("ZEST_SOAK_CONNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let h = ClusterHarness::spawn(&HarnessConfig {
        n: 512,
        dim: 16,
        shards: 2,
        replicas: 1,
        seed: 13,
        service_workers: 2,
        max_connections: conns + 16,
        ..HarnessConfig::default()
    })
    .unwrap();
    // The probe holds its own connection outside the soak population.
    let probe = PartitionClient::connect(h.addr.clone(), ClientConfig::default()).unwrap();

    let client_errors = AtomicUsize::new(0);
    let answered = AtomicUsize::new(0);
    // Barriers put the main thread in lockstep with the population:
    // every connection is open and has served wave 1 when `s1` is
    // scraped, and wave 2 only starts after it.
    let ready = std::sync::Barrier::new(conns + 1);
    let go2 = std::sync::Barrier::new(conns + 1);
    let s1 = std::thread::scope(|scope| {
        for i in 0..conns {
            let (h, ready, go2, client_errors, answered) =
                (&h, &ready, &go2, &client_errors, &answered);
            scope.spawn(move || {
                let wave = |client: &PartitionClient, seed: u64| {
                    let q = Rng::seeded(seed).unit_vec(16);
                    match client.estimate(EstimateSpec::new(q)) {
                        Ok(r) if r.z.is_finite() && r.z > 0.0 => {
                            answered.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {
                            client_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                };
                // One pooled connection per simulated client, held open
                // across both waves (peak concurrency == `conns`).
                let client = PartitionClient::connect(
                    h.addr.clone(),
                    ClientConfig {
                        max_idle: 1,
                        ..ClientConfig::default()
                    },
                );
                let client = match client {
                    Ok(c) => c,
                    Err(_) => {
                        client_errors.fetch_add(1, Ordering::Relaxed);
                        ready.wait();
                        go2.wait();
                        return;
                    }
                };
                wave(&client, i as u64);
                ready.wait();
                go2.wait();
                wave(&client, (i + conns) as u64);
            });
        }
        ready.wait();
        let s1 = probe.get_metrics().unwrap();
        go2.wait();
        s1
    });
    let s2 = probe.get_metrics().unwrap();

    assert_eq!(
        client_errors.load(Ordering::Relaxed),
        0,
        "soak must complete with zero client/protocol errors"
    );
    assert_eq!(answered.load(Ordering::Relaxed), conns * 2);
    // Zero protocol errors server-side too, at both scrape points.
    assert_eq!(s1.counter("net_wire_errors"), 0, "{:?}", s1.counters);
    assert_eq!(s2.counter("net_wire_errors"), 0, "{:?}", s2.counters);
    assert_eq!(s1.counter("net_rejected"), 0, "limit sized for the soak");
    // All soak connections (plus the probe) were open at scrape 1.
    assert!(
        s1.counter("net_active") >= conns as u64,
        "want ≥{conns} concurrently open connections, gauge says {}",
        s1.counter("net_active")
    );
    assert!(s1.counter("net_accepted") >= conns as u64 + 1);
    // Monotone frame counters: wave 1 = a ping + an estimate per
    // connection; wave 2 strictly advances both directions.
    assert!(s1.counter("net_frames_in") >= 2 * conns as u64);
    assert!(s2.counter("net_frames_in") >= s1.counter("net_frames_in") + conns as u64);
    assert!(s2.counter("net_frames_out") >= s1.counter("net_frames_out") + conns as u64);
    assert!(s2.counter("net_accepted") >= s1.counter("net_accepted"));

    drop(probe);
    h.shutdown();
}
