//! Chaos suite: replica failover under injected faults.
//!
//! * **Acceptance**: killing one replica of **each** shard mid-load
//!   yields zero failed client requests and bit-identical answers vs
//!   the monolithic reference, for S ∈ {1, 2, 4} × R ∈ {2, 3}; a
//!   publish still lands while the replicas are down; and the killed
//!   replicas re-heal to the lockstep epoch within one `refresh()`
//!   after reconnecting.
//! * The fault proxy itself: transparent forwarding, frame drops
//!   surfacing as timeouts, mid-frame cuts surfacing as transient
//!   transport errors — the vocabulary the failover layer must absorb.
//! * A seeded fault schedule (delays + mid-frame cuts on one replica's
//!   connections) over a full request load: every request succeeds
//!   bit-exactly despite the noise.
//!
//! Everything runs over UDS with in-process servers; the proxy is
//! `zest::testing::fault::FaultProxy`.
#![cfg(unix)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;
use zest::coordinator::ServiceMetrics;
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::net::client::ClientConfig;
use zest::net::remote::{aligned_split, RemoteCluster, RemoteShard};
use zest::net::server::{Server, ServerConfig};
use zest::net::shard::ShardWorker;
use zest::net::Addr;
use zest::store::{exp_sum_view, ShardedStore};
use zest::testing::fault::{FaultMode, FaultProxy, FaultSchedule};

static SOCKET_SEQ: AtomicUsize = AtomicUsize::new(0);

fn sock_addr(tag: &str) -> Addr {
    let seq = SOCKET_SEQ.fetch_add(1, Ordering::SeqCst);
    Addr::Unix(std::env::temp_dir().join(format!(
        "zest-chaos-{}-{tag}-{seq}.sock",
        std::process::id()
    )))
}

fn store(n: usize, d: usize) -> EmbeddingStore {
    generate(&SynthConfig {
        n,
        d,
        ..SynthConfig::tiny()
    })
}

fn spawn_worker(block: EmbeddingStore, tag: &str) -> (Server, Addr) {
    let addr = sock_addr(tag);
    let metrics = Arc::new(ServiceMetrics::new());
    let server = Server::serve(
        &addr,
        Arc::new(ShardWorker::new(block).with_metrics(metrics.clone())),
        ServerConfig::default(),
        metrics,
    )
    .unwrap();
    let bound = server.local_addr().clone();
    (server, bound)
}

/// S shards × R replicas: replicas of one shard serve identical blocks.
/// Replica 0 of every shard is reached **through a fault proxy**; the
/// rest are direct. Returns (servers, proxies, groups) with
/// `groups[s][0]` = shard `s`'s proxied replica.
fn spawn_replicated(
    s: &EmbeddingStore,
    shards: usize,
    replicas: usize,
    tag: &str,
) -> (Vec<Server>, Vec<FaultProxy>, Vec<Vec<Addr>>) {
    let mut servers = Vec::new();
    let mut proxies = Vec::new();
    let mut groups = Vec::new();
    for (i, block) in aligned_split(s, shards).into_iter().enumerate() {
        let mut group = Vec::new();
        for r in 0..replicas {
            let (server, addr) = spawn_worker(block.clone(), &format!("{tag}-s{i}r{r}"));
            servers.push(server);
            if r == 0 {
                let proxy =
                    FaultProxy::start(&sock_addr(&format!("{tag}-p{i}")), addr).unwrap();
                group.push(proxy.addr().clone());
                proxies.push(proxy);
            } else {
                group.push(addr);
            }
        }
        groups.push(group);
    }
    (servers, proxies, groups)
}

/// The fault proxy's vocabulary, end to end against a real shard
/// worker: transparent forwarding, dropped response frames surfacing
/// as a (transient) timeout, mid-frame cuts surfacing as a transient
/// transport error, and recovery after `restore()`.
#[test]
fn fault_proxy_forwards_drops_and_cuts() {
    let s = store(96, 8);
    let (server, upstream) = spawn_worker(s.clone(), "proxy-sanity");
    let proxy = FaultProxy::start(&sock_addr("proxy-sanity"), upstream).unwrap();
    let cfg = ClientConfig {
        read_timeout: Some(Duration::from_millis(400)),
        ..ClientConfig::default()
    };

    // Forward: the proxy is invisible.
    let (shard, (len, dim, epoch)) = RemoteShard::connect(proxy.addr().clone(), cfg.clone()).unwrap();
    assert_eq!((len, dim, epoch), (96, 8, 0));
    let q = s.row(3).to_vec();
    let want = exp_sum_view(&ShardedStore::split(&s, 1), &q);
    assert_eq!(shard.exp_sum_chain(0.0, &q).unwrap().to_bits(), want.to_bits());

    // DropFrames: the response never arrives → the call errs (timeout)
    // and the error is transient (exactly what failover keys on).
    proxy.set_mode(FaultMode::DropFrames(1));
    let err = shard.exp_sum_chain(0.0, &q).unwrap_err();
    assert!(err.is_transient(), "dropped frame surfaced as {err}");

    // CutAfter: the connection dies mid-frame → transient again. The
    // slot reconnects through the proxy on the next call.
    proxy.restore();
    proxy.set_mode(FaultMode::CutAfter(7));
    let err = shard.exp_sum_chain(0.0, &q).unwrap_err();
    assert!(err.is_transient(), "mid-frame cut surfaced as {err}");

    // Restore: the same handle heals by reconnecting lazily.
    proxy.restore();
    assert_eq!(shard.exp_sum_chain(0.0, &q).unwrap().to_bits(), want.to_bits());

    drop(shard);
    drop(proxy);
    server.shutdown();
}

/// ACCEPTANCE (tentpole pin): kill one replica of **each** shard in
/// the middle of a request load. Every request succeeds, every answer
/// is bit-identical to the monolithic reference, the failover counter
/// ticks, a publish lands while the replicas are down, and one
/// `refresh()` after the replicas come back restores full health and
/// lockstep (verified against the replica directly).
#[test]
fn kill_one_replica_per_shard_mid_load_is_invisible() {
    for shards in [1usize, 2, 4] {
        for replicas in [2usize, 3] {
            let s = store(240, 8);
            let (servers, proxies, groups) =
                spawn_replicated(&s, shards, replicas, &format!("kill-{shards}x{replicas}"));
            let cluster = Arc::new(
                RemoteCluster::connect_groups(
                    &groups,
                    ClientConfig {
                        read_timeout: Some(Duration::from_secs(5)),
                        ..ClientConfig::default()
                    },
                )
                .unwrap(),
            );
            assert_eq!(cluster.len(), 240);
            assert_eq!(
                cluster.replica_status(),
                vec![vec![true; replicas]; shards]
            );

            let qs: Vec<Vec<f32>> = (0..6).map(|i| s.row(i * 37 + 2).to_vec()).collect();
            let sharded = ShardedStore::split(&s, shards);
            let want: Vec<f64> = qs.iter().map(|q| exp_sum_view(&sharded, q)).collect();

            // Load: 12 request waves; halfway through, kill replica 0
            // of EVERY shard (sever live connections + refuse new
            // ones). Not one request may fail, and every answer stays
            // bit-identical.
            for wave in 0..12 {
                if wave == 6 {
                    for proxy in &proxies {
                        proxy.set_mode(FaultMode::Refuse);
                        proxy.cut_all();
                    }
                }
                for (q, w) in qs.iter().zip(&want) {
                    let got = cluster
                        .exp_sum(q)
                        .unwrap_or_else(|e| panic!("S={shards} R={replicas} wave {wave}: {e}"));
                    assert_eq!(
                        got.to_bits(),
                        w.to_bits(),
                        "S={shards} R={replicas} wave {wave}: {got} vs {w}"
                    );
                }
                let got = cluster.exp_sum_batch(&qs).unwrap();
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits());
                }
            }
            assert!(
                cluster.failovers() > 0,
                "S={shards} R={replicas}: the kill never triggered a failover"
            );

            // A publish lands while every shard's replica 0 is dead:
            // the live peers carry it (R ≥ 2 everywhere).
            let extra = store(8, 8);
            let epoch = cluster.add_categories(&extra).unwrap();
            assert_eq!(epoch, 1);
            assert_eq!(cluster.len(), 248);
            let dead_are_unhealthy = cluster
                .replica_status()
                .iter()
                .all(|g| !g[0] && g[1..].iter().all(|&h| h));
            assert!(
                dead_are_unhealthy,
                "replica_status after kill+publish: {:?}",
                cluster.replica_status()
            );

            // Reconnect + one refresh(): the killed replicas missed the
            // commit (and possibly the prepare); the publish-log replay
            // restores lockstep and full health.
            for proxy in &proxies {
                proxy.restore();
            }
            cluster.refresh().unwrap();
            assert_eq!(
                cluster.replica_status(),
                vec![vec![true; replicas]; shards],
                "S={shards} R={replicas}: heal did not restore full health"
            );
            assert_eq!(cluster.epoch(), 1);

            // The healed replicas really serve the published epoch:
            // ask each one directly, through its proxy. The appended
            // rows joined the LAST shard, all other block lengths are
            // unchanged.
            let orig_lens: Vec<usize> =
                aligned_split(&s, shards).iter().map(|b| b.len()).collect();
            for (shard_idx, proxy) in proxies.iter().enumerate() {
                let (_, (len, _, epoch)) =
                    RemoteShard::connect(proxy.addr().clone(), ClientConfig::default()).unwrap();
                assert_eq!(
                    epoch, 1,
                    "S={shards} R={replicas}: replica 0 of shard {shard_idx} not at lockstep"
                );
                let want_len =
                    orig_lens[shard_idx] + if shard_idx == shards - 1 { 8 } else { 0 };
                assert_eq!(len, want_len);
            }

            // And answers over the grown set stay bit-exact with the
            // full replica set back in rotation (appends land on the
            // last worker, so 4-aligned boundaries are preserved and
            // the monolithic view matches bit for bit).
            let mut combined = s.data().to_vec();
            combined.extend_from_slice(extra.data());
            let grown = EmbeddingStore::from_data(248, 8, combined).unwrap();
            for q in &qs {
                let w = exp_sum_view(&grown, q);
                assert_eq!(cluster.exp_sum(q).unwrap().to_bits(), w.to_bits());
            }

            drop(cluster);
            drop(proxies);
            for server in servers {
                server.shutdown();
            }
        }
    }
}

/// A seeded fault schedule — delays and mid-frame cuts assigned
/// per-connection from one seed — runs under a full request load on
/// replica 0's link. Every request must still succeed bit-exactly:
/// failover absorbs the cut connections, delays just slow their
/// requests down. Replayable from the seed alone.
#[test]
fn seeded_fault_schedule_never_corrupts_answers() {
    let (shards, replicas) = (2usize, 2usize);
    let s = store(160, 8);
    let (servers, proxies, groups) = spawn_replicated(&s, shards, replicas, "seeded");
    let cluster = Arc::new(
        RemoteCluster::connect_groups(
            &groups,
            ClientConfig {
                read_timeout: Some(Duration::from_secs(5)),
                ..ClientConfig::default()
            },
        )
        .unwrap(),
    );
    // Pin the schedule AFTER the healthy connect, then sever the
    // initial connections so every reconnect samples a schedule slot.
    for proxy in &proxies {
        proxy.set_schedule(Some(FaultSchedule::seeded(0xC4A05, 16)));
        proxy.cut_all();
    }
    let qs: Vec<Vec<f32>> = (0..4).map(|i| s.row(i * 31 + 1).to_vec()).collect();
    let sharded = ShardedStore::split(&s, shards);
    let want: Vec<f64> = qs.iter().map(|q| exp_sum_view(&sharded, q)).collect();
    for _wave in 0..10 {
        let got = cluster.exp_sum_batch(&qs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
    // Each proxy saw its initial connect plus at least one reconnect
    // after the cut (round-robin guarantees the proxied replica is
    // picked again).
    assert!(
        proxies.iter().map(FaultProxy::accepted).sum::<usize>() >= 4,
        "schedule never forced a reconnect: {:?}",
        proxies.iter().map(FaultProxy::accepted).collect::<Vec<_>>()
    );
    drop(cluster);
    drop(proxies);
    for server in servers {
        server.shutdown();
    }
}
