//! Failure-injection tests: the system's behaviour at the edges —
//! poisoned retrievals, degenerate queries, overload, bad artifacts,
//! and pathological shapes.

use std::sync::Arc;
use zest::coordinator::{
    BackpressurePolicy, BatcherConfig, EstimateSpec, PartitionService, Router, ServiceConfig,
    SubmitError,
};
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::{mimps::Mimps, EstimateContext, Estimator, EstimatorKind};
use zest::mips::brute::BruteIndex;
use zest::mips::MipsIndex;
use zest::oracle::{OracleIndex, RetrievalError};
use zest::util::rng::Rng;

fn store() -> EmbeddingStore {
    generate(&SynthConfig {
        n: 1000,
        d: 16,
        ..SynthConfig::tiny()
    })
}

/// The paper's pathological case |q| = 0: Z = N exactly; MIMPS must get
/// it exactly right too (every exp score is 1).
#[test]
fn zero_query_gives_exactly_n() {
    let s = store();
    let index = BruteIndex::new(&s);
    let q = vec![0f32; s.dim()];
    assert!((index.partition(&q) - s.len() as f64).abs() < 1e-9);
    let mut rng = Rng::seeded(0);
    let mut ctx = EstimateContext::new(&s, &index, &mut rng);
    let z = Mimps::new(50, 50).estimate(&mut ctx, &q);
    assert!(
        (z - s.len() as f64).abs() < 1e-6 * s.len() as f64,
        "MIMPS on zero query: {z}"
    );
}

/// NaN queries must not hang or panic the estimators; outputs may be NaN
/// but the service must stay alive.
#[test]
fn nan_query_does_not_wedge_service() {
    let s = Arc::new(store());
    let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&s));
    let svc = PartitionService::start(
        s.clone(),
        index,
        Router::new(Default::default()),
        ServiceConfig {
            workers: 1,
            ..Default::default()
        },
        None,
    );
    let mut bad = vec![0f32; s.dim()];
    bad[0] = f32::NAN;
    let r = svc.estimate(EstimateSpec::new(bad).kind(EstimatorKind::Mimps).k(10).l(10));
    // Either a response (possibly NaN) or nothing — but not a hang/panic.
    assert!(r.is_ok());
    // The service still answers a sane request afterwards.
    let ok = svc
        .estimate(
            EstimateSpec::new(s.row(0).to_vec())
                .kind(EstimatorKind::Mimps)
                .k(10)
                .l(10),
        )
        .unwrap();
    assert!(ok.z.is_finite());
    svc.shutdown();
}

/// A poisoned index that always hides the true top-1 (Table 3's failure
/// mode as a live index): MIMPS degrades but stays finite and positive.
#[test]
fn poisoned_index_degrades_gracefully() {
    let s = store();
    let clean = OracleIndex::new(BruteIndex::new(&s));
    let poisoned = OracleIndex::with_error(BruteIndex::new(&s), RetrievalError::drop_first());
    let brute = BruteIndex::new(&s);
    let q = s.row(950).to_vec(); // rare, peaked query
    let want = brute.partition(&q);
    let mut rng = Rng::seeded(1);
    let est = Mimps::new(100, 100);
    let mut ctx = EstimateContext::new(&s, &clean, &mut rng);
    let z_clean = est.estimate(&mut ctx, &q);
    let mut ctx = EstimateContext::new(&s, &poisoned, &mut rng);
    let z_poisoned = est.estimate(&mut ctx, &q);
    assert!(z_poisoned.is_finite() && z_poisoned > 0.0);
    let e_clean = zest::metrics::abs_rel_err_pct(z_clean, want);
    let e_poisoned = zest::metrics::abs_rel_err_pct(z_poisoned, want);
    assert!(
        e_poisoned > e_clean,
        "poisoning must hurt: {e_clean} vs {e_poisoned}"
    );
}

/// k = N (head covers everything): estimators degrade to exact, tail
/// sampling finds an empty complement without panicking.
#[test]
fn head_covering_all_categories() {
    let s = store();
    let index = BruteIndex::new(&s);
    let q = s.row(1).to_vec();
    let want = index.partition(&q);
    let mut rng = Rng::seeded(2);
    let mut ctx = EstimateContext::new(&s, &index, &mut rng);
    let z = Mimps::new(s.len(), 100).estimate(&mut ctx, &q);
    assert!((z - want).abs() < 1e-6 * want);
}

/// Overloaded shed-policy service rejects but never deadlocks, and all
/// accepted requests eventually complete.
#[test]
fn overload_sheds_but_completes_accepted() {
    let s = Arc::new(generate(&SynthConfig {
        n: 3000,
        d: 32,
        ..SynthConfig::tiny()
    }));
    let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::with_threads(&s, 1));
    let svc = PartitionService::start(
        s.clone(),
        index,
        Router::new(Default::default()),
        ServiceConfig {
            workers: 1,
            queue_capacity: 4,
            backpressure: BackpressurePolicy::Shed,
            batcher: BatcherConfig {
                max_batch: 2,
                max_wait: std::time::Duration::from_micros(500),
            },
            ..Default::default()
        },
        None,
    );
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for i in 0..300 {
        match svc.submit(EstimateSpec::new(s.row(i % s.len()).to_vec())) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected {e}"),
        }
    }
    let done = accepted
        .into_iter()
        .filter(|rx| rx.recv().is_ok())
        .count();
    assert!(done > 0, "some requests must complete");
    assert_eq!(
        svc.metrics().shed as usize, shed,
        "metrics must count shed load"
    );
    svc.shutdown();
}

/// Every replica of a shard down at once: failover has nowhere left to
/// go, so the scatter surfaces a **typed** backend failure — the caller
/// sees a clean `SubmitError`, `backend_errors` and the per-shard error
/// counter tick, and nothing panics or hangs. (One replica down is the
/// invisible case — covered by `tests/chaos.rs`; this is the floor
/// below it.)
#[test]
fn all_replicas_down_is_a_typed_error_not_a_hang() {
    use zest::coordinator::ClusterBackend;
    use zest::net::client::ClientConfig;
    use zest::net::server::{Server, ServerConfig};
    use zest::net::shard::ShardWorker;
    use zest::net::Addr;
    use zest::coordinator::ServiceMetrics;

    let s = generate(&SynthConfig {
        n: 240,
        d: 8,
        ..SynthConfig::tiny()
    });
    // One shard × two replicas, over loopback TCP (a killed listener
    // refuses new connections immediately — the fast-failure path).
    let mut servers = Vec::new();
    let mut group = Vec::new();
    for _ in 0..2 {
        let server = Server::serve(
            &Addr::Tcp("127.0.0.1:0".to_string()),
            Arc::new(ShardWorker::new(s.clone())),
            ServerConfig::default(),
            Arc::new(ServiceMetrics::new()),
        )
        .unwrap();
        group.push(server.local_addr().clone());
        servers.push(server);
    }
    let backend = ClusterBackend::connect_groups(
        &[group],
        ClientConfig {
            read_timeout: Some(std::time::Duration::from_secs(5)),
            ..ClientConfig::default()
        },
    )
    .unwrap();
    let cluster = backend.cluster().clone();
    let svc = PartitionService::start_with_backend(
        backend,
        ServiceConfig {
            workers: 1,
            ..Default::default()
        },
    );
    cluster.set_metrics(svc.metrics_handle());

    // Healthy sanity pass.
    let q = s.row(3).to_vec();
    let ok = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
    assert!(ok.z.is_finite());

    // Take BOTH replicas down, then ask again: the batch leader's
    // scatter exhausts the replica set, the backend error drops the
    // reply channel, and the caller observes `Closed` — typed, prompt,
    // no panic.
    for server in servers {
        server.shutdown();
    }
    let err = svc
        .estimate(EstimateSpec::new(q.clone()))
        .expect_err("a fully-down shard must surface an error");
    assert!(
        matches!(err, SubmitError::Closed | SubmitError::DeadlineExceeded),
        "want a typed channel-drop error, got {err}"
    );

    // The failure is visible in metrics: the backend error counted,
    // attributed to the one shard everything failed on.
    let m = svc.metrics();
    assert!(m.backend_errors >= 1, "{m}");
    assert!(
        m.shard_stats.iter().any(|st| st.shard == 0 && st.errors >= 1),
        "per-shard error attribution missing: {m}"
    );

    // Still alive: the service keeps answering (with errors) rather
    // than wedging, and shuts down cleanly.
    assert!(svc.estimate(EstimateSpec::new(q)).is_err());
    svc.shutdown();
}

/// Corrupt artifacts directory: runtime load fails with a clear error and
/// no thread leak (join handle returns).
#[test]
fn corrupt_artifacts_fail_cleanly() {
    let dir = std::env::temp_dir().join("zest_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("meta.json"), "{not json").unwrap();
    let err = zest::runtime::ArtifactsMeta::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("parse"));
    std::fs::write(
        dir.join("meta.json"),
        r#"{"config": {}, "graphs": {"g": {"file": "missing.hlo.txt", "args": []}}}"#,
    )
    .unwrap();
    let res = zest::runtime::spawn_runtime_thread(dir.clone(), None);
    assert!(res.is_err(), "missing hlo file must fail load");
    std::fs::remove_dir_all(&dir).ok();
}

/// Mismatched input shapes are rejected by the runtime with a
/// descriptive error rather than a crash in XLA.
#[test]
fn runtime_rejects_wrong_shapes() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = zest::runtime::Runtime::load_subset(&dir, &["partition_chunk"]).unwrap();
    let err = rt
        .run(
            "partition_chunk",
            &[
                zest::runtime::HostTensor::f32(vec![0.0; 4], &[2, 2]),
                zest::runtime::HostTensor::f32(vec![0.0; 2], &[2]),
            ],
        )
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shape"), "unhelpful error: {msg}");
    let err = rt.run("partition_chunk", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("expected"));
    let err = rt.run("nope", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown graph"));
}

/// Single-element and single-dimension stores work through the whole
/// estimator stack.
#[test]
fn degenerate_store_shapes() {
    let s = EmbeddingStore::from_data(1, 1, vec![0.5]).unwrap();
    let index = BruteIndex::with_threads(&s, 1);
    let q = vec![2.0f32];
    let want = (1.0f64).exp(); // exp(0.5 * 2.0)
    assert!((index.partition(&q) - want).abs() < 1e-6);
    let mut rng = Rng::seeded(3);
    let mut ctx = EstimateContext::new(&s, &index, &mut rng);
    let z = Mimps::new(1, 1).estimate(&mut ctx, &q);
    assert!((z - want).abs() < 1e-6);
}
