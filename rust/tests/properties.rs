//! Property-based tests (via the `testing::prop` substrate) over the
//! crate's core invariants. Each property runs many seeded random cases;
//! failures report the reproducing seed.

use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::{
    mimps::Mimps, mince, nmimps::Nmimps, uniform::Uniform, EstimateContext, Estimator,
};
use zest::linalg;
use zest::mips::brute::BruteIndex;
use zest::mips::transform::MipsTransform;
use zest::mips::{select_top_k, MipsIndex};
use zest::testing::prop::{assert_close, check};
use zest::util::rng::Rng;

fn random_store(rng: &mut Rng, max_n: usize, max_d: usize) -> EmbeddingStore {
    let n = rng.range(8, max_n);
    let d = rng.range(2, max_d);
    let data: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 0.5).collect();
    EmbeddingStore::from_data(n, d, data).unwrap()
}

/// MIMPS with k + l ≥ N is exact for any store and query.
#[test]
fn prop_mimps_exact_when_budget_covers_n() {
    check(40, |rng| {
        let store = random_store(rng, 120, 24);
        let n = store.len();
        let index = BruteIndex::with_threads(&store, 1);
        let q = store.row(rng.below(n)).to_vec();
        let want = index.partition(&q);
        let k = rng.range(1, n);
        let l = n - k;
        let mut ctx = EstimateContext::new(&store, &index, rng);
        let z = Mimps::new(k, l).estimate(&mut ctx, &q);
        assert_close(z, want, 1e-5, "MIMPS with full budget")
    });
}

/// NMIMPS is monotone in k and bounded above by Z.
#[test]
fn prop_nmimps_monotone_and_bounded() {
    check(40, |rng| {
        let store = random_store(rng, 150, 16);
        let index = BruteIndex::with_threads(&store, 1);
        let q = store.row(0).to_vec();
        let z = index.partition(&q);
        let mut prev = 0.0;
        for frac in [1usize, 4, 16] {
            let k = (store.len() / frac).max(1);
            let mut ctx = EstimateContext::new(&store, &index, rng);
            let est = Nmimps::new(k).estimate(&mut ctx, &q);
            if est > z * (1.0 + 1e-5) {
                return Err(format!("NMIMPS {est} exceeds Z {z}"));
            }
            // fracs iterate k descending, so est should also descend.
            if frac > 1 && est > prev * (1.0 + 1e-5) {
                return Err(format!("NMIMPS not monotone: {est} > {prev}"));
            }
            prev = est;
        }
        Ok(())
    });
}

/// Estimators are invariant under permutation of the category set
/// (same estimate distribution — tested via exactness-preserving cases:
/// full-budget MIMPS, which must give identical Z on permuted stores).
#[test]
fn prop_category_permutation_invariance() {
    check(25, |rng| {
        let store = random_store(rng, 80, 12);
        let n = store.len();
        let d = store.dim();
        // Build a permuted copy.
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let mut data = vec![0f32; n * d];
        for (new_i, &old_i) in perm.iter().enumerate() {
            data[new_i * d..(new_i + 1) * d].copy_from_slice(store.row(old_i));
        }
        let permuted = EmbeddingStore::from_data(n, d, data).unwrap();
        let q = store.row(0).to_vec();
        let a = BruteIndex::with_threads(&store, 1).partition(&q);
        let b = BruteIndex::with_threads(&permuted, 1).partition(&q);
        assert_close(a, b, 1e-9, "Z under permutation")
    });
}

/// The Bachrach lift preserves inner-product order exactly.
#[test]
fn prop_transform_preserves_order() {
    check(30, |rng| {
        let store = random_store(rng, 100, 16);
        let t = MipsTransform::lift(&store);
        let q = rng.normal_vec(store.dim());
        let lq = t.lift_query(&q);
        // Top-5 by inner product == bottom-5 by lifted distance.
        let mut scores: Vec<f32> = (0..store.len())
            .map(|i| linalg::dot(store.row(i), &q))
            .collect();
        let top = select_top_k(&scores, 5);
        let mut by_dist: Vec<(usize, f32)> = (0..store.len())
            .map(|i| (i, linalg::dist_sq(t.row(i), &lq)))
            .collect();
        by_dist.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (h, (i, _)) in top.iter().zip(by_dist.iter()) {
            if h.idx != *i {
                // Allow swaps only between float-tied scores.
                let s_a = scores[h.idx];
                let s_b = scores[*i];
                if (s_a - s_b).abs() > 1e-5 * (1.0 + s_a.abs()) {
                    return Err(format!(
                        "order violated: ip-rank {} vs dist-rank {}",
                        h.idx, i
                    ));
                }
            }
        }
        scores.clear();
        Ok(())
    });
}

/// select_top_k returns a sorted prefix of the full descending sort.
#[test]
fn prop_select_top_k_is_sorted_prefix() {
    check(60, |rng| {
        let n = rng.range(1, 400);
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let k = rng.range(0, n + 1);
        let hits = select_top_k(&scores, k);
        if hits.len() != k.min(n) {
            return Err(format!("wrong count {} for k={k} n={n}", hits.len()));
        }
        let mut sorted: Vec<f32> = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (h, want) in hits.iter().zip(sorted.iter()) {
            if (h.score - want).abs() > 0.0 {
                return Err(format!("hit {} != sorted {}", h.score, want));
            }
        }
        for w in hits.windows(2) {
            if w[1].score > w[0].score {
                return Err("descending order violated".to_string());
            }
        }
        Ok(())
    });
}

/// The MINCE solver always lands on a stationary point with positive Z,
/// for arbitrary positive score scales, under both Newton and Halley.
#[test]
fn prop_mince_solver_stationary() {
    check(50, |rng| {
        let k = rng.range(1, 40);
        let l = rng.range(1, 80);
        let scale = (rng.normal() * 4.0).exp();
        let a: Vec<f64> = (0..k).map(|_| (rng.normal()).exp() * scale * 10.0).collect();
        let b: Vec<f64> = (0..l).map(|_| (rng.normal()).exp() * scale).collect();
        for solver in [mince::Solver::Newton, mince::Solver::Halley] {
            let r = mince::solve(&a, &b, a.iter().sum(), solver);
            if !(r.z.is_finite() && r.z > 0.0) {
                return Err(format!("{solver:?}: bad root {}", r.z));
            }
        }
        Ok(())
    });
}

/// Uniform estimator: sampling all N categories without replacement is
/// exact regardless of data.
#[test]
fn prop_uniform_full_sample_exact() {
    check(30, |rng| {
        let store = random_store(rng, 60, 10);
        let index = BruteIndex::with_threads(&store, 1);
        let q = store.row(rng.below(store.len())).to_vec();
        let want = index.partition(&q);
        let mut ctx = EstimateContext::new(&store, &index, rng);
        let z = Uniform::new(store.len()).estimate(&mut ctx, &q);
        assert_close(z, want, 1e-5, "Uniform(l=N)")
    });
}

/// Tail samples never collide with the head and never repeat — for any
/// head size, tail size, and store.
#[test]
fn prop_tail_sampling_disjoint_distinct() {
    check(50, |rng| {
        let store = random_store(rng, 200, 8);
        let index = BruteIndex::with_threads(&store, 1);
        let q = store.row(0).to_vec();
        let k = rng.range(0, store.len());
        let head = index.top_k(&q, k);
        let l = rng.range(0, store.len() + 10);
        let sample = zest::estimators::tail::sample_tail(&store, &head, l, &q, rng);
        let head_set: std::collections::HashSet<usize> = head.iter().map(|h| h.idx).collect();
        let mut seen = std::collections::HashSet::new();
        for &i in &sample.indices {
            if head_set.contains(&i) {
                return Err(format!("tail index {i} is in the head"));
            }
            if !seen.insert(i) {
                return Err(format!("duplicate tail index {i}"));
            }
        }
        let expect = l.min(store.len() - head.len());
        if sample.indices.len() != expect {
            return Err(format!(
                "tail size {} != expected {expect}",
                sample.indices.len()
            ));
        }
        Ok(())
    });
}

/// gemv_blocked == gemv == per-row dot for arbitrary shapes.
#[test]
fn prop_gemv_variants_agree() {
    check(50, |rng| {
        let rows = rng.range(1, 70);
        let d = rng.range(1, 70);
        let m: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut a = vec![0f32; rows];
        let mut b = vec![0f32; rows];
        linalg::gemv(&m, rows, d, &q, &mut a);
        linalg::gemv_blocked(&m, rows, d, &q, &mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if (x - y).abs() > 1e-3 * (1.0 + x.abs()) {
                return Err(format!("row {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

/// Store save/load round-trips bit-exactly for random contents.
#[test]
fn prop_store_roundtrip_bit_exact() {
    let dir = std::env::temp_dir().join("zest_prop_store");
    std::fs::create_dir_all(&dir).unwrap();
    check(15, |rng| {
        let store = random_store(rng, 60, 20);
        let path = dir.join(format!("s{}.bin", rng.next_u64()));
        store.save(&path).map_err(|e| e.to_string())?;
        let loaded = EmbeddingStore::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if loaded != store {
            return Err("roundtrip mismatch".to_string());
        }
        Ok(())
    });
}

/// Every randomly generated wire message survives encode → frame →
/// unframe → decode unchanged (the codec is total on its own output).
#[test]
fn prop_wire_codec_roundtrips() {
    use zest::coordinator::Precision;
    use zest::estimators::EstimatorKind;
    use zest::mips::Hit;
    use zest::net::wire::{self, ErrorCode, Estimate, Request, Response};

    fn random_query(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    fn random_queries(rng: &mut Rng) -> Vec<Vec<f32>> {
        let d = rng.range(1, 24);
        let n = rng.below(6);
        (0..n).map(|_| random_query(rng, d)).collect()
    }

    fn random_kind(rng: &mut Rng) -> EstimatorKind {
        let all = EstimatorKind::all();
        all[rng.below(all.len())]
    }

    fn random_precision(rng: &mut Rng) -> Precision {
        if rng.below(2) == 0 {
            Precision::BitExact
        } else {
            Precision::Pipelined
        }
    }

    fn random_blob(rng: &mut Rng) -> zest::obs::MetricsBlob {
        let counters = (0..rng.below(4))
            .map(|i| (format!("counter_{i}"), rng.next_u64() >> 16))
            .collect();
        let hists = (0..rng.below(3))
            .map(|i| {
                let h = zest::obs::Histogram::new();
                for _ in 0..rng.below(40) {
                    h.record(rng.next_u64() >> 40);
                }
                (format!("hist_{i}_ns"), h.snapshot())
            })
            .collect();
        zest::obs::MetricsBlob { counters, hists }
    }

    check(200, |rng| {
        let req = match rng.below(15) {
            0 => Request::Ping,
            1 => Request::Manifest,
            2 => Request::Estimate {
                kind: random_kind(rng),
                k: rng.next_u64() >> 32,
                l: rng.next_u64() >> 32,
                precision: random_precision(rng),
                deadline_ns: rng.next_u64() >> 8,
                query: random_query(rng, rng.range(1, 32)),
            },
            3 => Request::EstimateBatch {
                kind: random_kind(rng),
                k: rng.below(1000) as u64,
                l: rng.below(1000) as u64,
                precision: random_precision(rng),
                deadline_ns: rng.next_u64() >> 8,
                queries: random_queries(rng),
            },
            4 => Request::TopK {
                k: rng.below(100) as u64,
                queries: random_queries(rng),
            },
            5 => Request::ExpSumChain {
                acc: rng.normal() * 1e6,
                query: random_query(rng, rng.range(1, 16)),
            },
            6 => Request::ExpSumChainBatch {
                acc_in: (0..rng.below(5)).map(|_| rng.normal()).collect(),
                queries: random_queries(rng),
            },
            7 => Request::ScoreIds {
                ids: (0..rng.below(20)).map(|_| rng.next_u64() >> 16).collect(),
                query: random_query(rng, rng.range(1, 16)),
            },
            8 => Request::PrepareAdd {
                token: rng.next_u64(),
                dim: rng.range(1, 8) as u64,
                rows: (0..rng.below(64)).map(|_| rng.normal() as f32).collect(),
            },
            9 => Request::PrepareRemove {
                token: rng.next_u64(),
                ids: (0..rng.below(10)).map(|_| rng.next_u64() >> 40).collect(),
            },
            10 => Request::Commit {
                token: rng.next_u64(),
            },
            11 => Request::FitFmbe {
                seed: rng.next_u64(),
                p_features: rng.below(100_000) as u64,
            },
            12 => Request::ExpSumPart {
                queries: random_queries(rng),
            },
            13 => Request::GetMetrics,
            _ => Request::Abort {
                token: rng.next_u64(),
            },
        };
        let req_id = rng.next_u64();
        let mut framed = Vec::new();
        wire::write_request(&mut framed, req_id, &req)
            .map_err(|e| format!("write_request: {e}"))?;
        let (got_id, got) = wire::read_request(&mut &framed[..])
            .map_err(|e| format!("read_request: {e}"))?
            .ok_or("unexpected EOF")?;
        if got_id != req_id {
            return Err(format!("request id mangled: {req_id} → {got_id}"));
        }
        if got != req {
            return Err(format!("request mangled: {req:?} → {got:?}"));
        }

        let resp = match rng.below(12) {
            0 => Response::Pong,
            1 => Response::Manifest {
                len: rng.next_u64() >> 20,
                dim: rng.below(2048) as u64,
                epoch: rng.below(1000) as u64,
            },
            2 => Response::Estimates(
                (0..rng.below(5))
                    .map(|_| Estimate {
                        z: rng.normal() * 1e10,
                        kind: random_kind(rng),
                        epoch: rng.below(100) as u64,
                        scorings: rng.below(1_000_000) as u64,
                        queue_wait_ns: rng.next_u64() >> 20,
                        exec_ns: rng.next_u64() >> 20,
                        served_from_cache: rng.below(2) == 1,
                    })
                    .collect(),
            ),
            3 => Response::Hits(
                (0..rng.below(4))
                    .map(|_| {
                        (0..rng.below(8))
                            .map(|_| Hit {
                                idx: rng.below(1 << 40),
                                score: rng.normal() as f32,
                            })
                            .collect()
                    })
                    .collect(),
            ),
            4 => Response::ExpSums((0..rng.below(6)).map(|_| rng.normal() * 1e30).collect()),
            5 => Response::Scores((0..rng.below(20)).map(|_| rng.normal() as f32).collect()),
            6 => Response::Prepared {
                epoch: rng.below(100) as u64,
            },
            7 => Response::Committed {
                epoch: rng.below(100) as u64,
            },
            8 => Response::Aborted,
            9 => Response::Lambdas {
                epoch: rng.below(100) as u64,
                lambdas: (0..rng.below(16)).map(|_| rng.normal() * 1e6).collect(),
            },
            10 => Response::Metrics(random_blob(rng)),
            _ => Response::Error {
                code: ErrorCode::from_u16((rng.below(12) + 1) as u16),
                message: format!("case {} says λ̃ ≠ Z", rng.below(1000)),
            },
        };
        let resp_id = rng.next_u64();
        let mut framed = Vec::new();
        wire::write_response(&mut framed, resp_id, &resp)
            .map_err(|e| format!("write_response: {e}"))?;
        let (got_id, got) = wire::read_response(&mut &framed[..])
            .map_err(|e| format!("read_response: {e}"))?
            .ok_or("unexpected EOF")?;
        if got_id != resp_id {
            return Err(format!("response id mangled: {resp_id} → {got_id}"));
        }
        if got != resp {
            return Err(format!("response mangled: {resp:?} → {got:?}"));
        }

        // v5 traced frames: the same response with a WireTimes annex
        // roundtrips both the message and the annex.
        let times = wire::WireTimes {
            handle_lag_ns: rng.next_u64() >> 20,
            exec_ns: rng.next_u64() >> 20,
        };
        let mut framed = Vec::new();
        wire::write_response_timed(&mut framed, resp_id, &resp, times)
            .map_err(|e| format!("write_response_timed: {e}"))?;
        let (got_id, got, got_times) = wire::read_response_timed(&mut &framed[..])
            .map_err(|e| format!("read_response_timed: {e}"))?
            .ok_or("unexpected EOF on traced frame")?;
        if got_id != resp_id || got != resp {
            return Err("traced response mangled".to_string());
        }
        if got_times != Some(times) {
            return Err(format!("times annex mangled: {times:?} → {got_times:?}"));
        }
        Ok(())
    });
}

/// Truncating a valid frame at any byte boundary never panics and never
/// yields a successfully decoded message — it is either a clean EOF (cut
/// before the first header byte) or a malformed-frame error.
#[test]
fn prop_wire_truncation_is_total() {
    use zest::net::wire::{self, Request, WireError};

    check(60, |rng| {
        let req = Request::ScoreIds {
            ids: (0..rng.range(1, 30)).map(|_| rng.next_u64() >> 32).collect(),
            query: (0..rng.range(1, 16)).map(|_| rng.normal() as f32).collect(),
        };
        let mut framed = Vec::new();
        wire::write_request(&mut framed, rng.next_u64(), &req).map_err(|e| format!("{e}"))?;
        let cut = rng.below(framed.len());
        match wire::read_request(&mut &framed[..cut]) {
            Ok(None) if cut == 0 => Ok(()),
            Ok(None) => Err(format!("cut {cut} of {} read as clean EOF", framed.len())),
            Ok(Some(_)) => Err(format!("cut {cut} of {} decoded a message", framed.len())),
            Err(WireError::Malformed(_)) => Ok(()),
            Err(e) => Err(format!("cut {cut}: unexpected error class {e}")),
        }
    });
}

/// The replica-failover decision functions, fuzzed over their whole
/// input space. Two functions gate every failover in
/// `net::remote::ReplicaSet`:
///
/// * `ClientError::is_transient` — retry-on-another-replica iff the
///   *connection or worker* failed, never when the *request* is bad.
///   Exactly `Wire`, `ConnectionClosed`, `ConnectionLost`, and a
///   `Remote { ConnLimit }` rejection are transient; every other remote
///   code and `Protocol` are fatal; `Shard` attribution layers must
///   never change the decision.
/// * `resend_safe` — blind re-send on a fresh connection is allowed for
///   every wire request except `Commit`, which may have already
///   executed when its response was lost.
///
/// This PR adds **no new wire messages** (failover is built from the
/// existing vocabulary), so there are no new golden-byte vectors —
/// `prop_wire_codec_roundtrips` above already covers every frame.
#[test]
fn prop_failover_retry_decision() {
    use zest::net::client::{resend_safe, ClientError};
    use zest::net::wire::{ErrorCode, Request, WireError};

    fn random_wire_error(rng: &mut Rng) -> WireError {
        match rng.below(5) {
            0 => WireError::Io(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "fuzzed reset",
            )),
            1 => WireError::BadMagic(*b"nope"),
            2 => WireError::BadVersion(rng.below(1 << 16) as u16),
            3 => WireError::FrameTooLarge(rng.below(1 << 40)),
            _ => WireError::Malformed(format!("fuzz {}", rng.below(1000))),
        }
    }

    /// A random base error plus the independently-computed expected
    /// classification (spelled out, not derived via the code under test).
    fn random_error(rng: &mut Rng) -> (ClientError, bool) {
        match rng.below(5) {
            0 => (ClientError::Wire(random_wire_error(rng)), true),
            1 => {
                let code = ErrorCode::from_u16(rng.below(13) as u16);
                let transient = code == ErrorCode::ConnLimit;
                (
                    ClientError::Remote {
                        code,
                        message: format!("fuzz {}", rng.below(1000)),
                    },
                    transient,
                )
            }
            2 => (
                ClientError::Protocol(format!("fuzz {}", rng.below(1000))),
                false,
            ),
            3 => (ClientError::ConnectionClosed, true),
            _ => (
                ClientError::ConnectionLost(format!("fuzz {}", rng.below(1000))),
                true,
            ),
        }
    }

    fn random_request(rng: &mut Rng) -> Request {
        match rng.below(8) {
            0 => Request::Ping,
            1 => Request::Manifest,
            2 => Request::ExpSumChain {
                acc: rng.normal(),
                query: (0..rng.range(1, 8)).map(|_| rng.normal() as f32).collect(),
            },
            3 => Request::ScoreIds {
                ids: (0..rng.below(8)).map(|_| rng.next_u64() >> 32).collect(),
                query: (0..rng.range(1, 8)).map(|_| rng.normal() as f32).collect(),
            },
            4 => Request::PrepareAdd {
                token: rng.next_u64(),
                dim: rng.range(1, 8) as u64,
                rows: (0..rng.below(32)).map(|_| rng.normal() as f32).collect(),
            },
            5 => Request::PrepareRemove {
                token: rng.next_u64(),
                ids: (0..rng.below(8)).map(|_| rng.next_u64() >> 40).collect(),
            },
            6 => Request::Abort {
                token: rng.next_u64(),
            },
            _ => Request::Commit {
                token: rng.next_u64(),
            },
        }
    }

    check(400, |rng| {
        let (mut err, want_transient) = random_error(rng);
        // Bury it under 0–3 layers of shard attribution: naming the
        // failing worker must never flip the retry decision.
        for _ in 0..rng.below(4) {
            err = ClientError::Shard {
                shard: rng.below(64),
                source: Box::new(err),
            };
        }
        if err.is_transient() != want_transient {
            return Err(format!(
                "is_transient({err}) = {}, want {want_transient}",
                err.is_transient()
            ));
        }

        let req = random_request(rng);
        let want_safe = !matches!(req, Request::Commit { .. });
        if resend_safe(&req) != want_safe {
            return Err(format!(
                "resend_safe({req:?}) = {}, want {want_safe}",
                resend_safe(&req)
            ));
        }
        Ok(())
    });
}

/// K-means-tree search with full budget equals brute top-k for any store.
#[test]
fn prop_tree_full_budget_exact() {
    check(10, |rng| {
        let store = random_store(rng, 400, 12);
        let tree = zest::mips::kmeans_tree::KMeansTreeIndex::build(
            &store,
            zest::mips::kmeans_tree::KMeansTreeConfig {
                branching: 4,
                leaf_size: 8,
                ..Default::default()
            },
        );
        let brute = BruteIndex::with_threads(&store, 1);
        let q = store.row(rng.below(store.len())).to_vec();
        let (hits, _) = tree.search_with_budget(&q, 5, store.len());
        let want = brute.top_k(&q, 5);
        for (h, w) in hits.iter().zip(&want) {
            if (h.score - w.score).abs() > 1e-5 {
                return Err(format!("tree {} vs brute {}", h.score, w.score));
            }
        }
        Ok(())
    });
}
