//! Batch-vs-single equivalence for the batched scoring engine: every
//! `EstimatorKind` through `Router::estimate_batch`, plus the index-level
//! `top_k_batch` / `partition_batch` primitives, must agree with the
//! per-query paths. Sampling estimators are compared under identical RNG
//! seeds (the batched paths consume the stream in submission order);
//! tolerances cover the scalar GEMM micro-kernel's different f32
//! accumulation order vs the per-query GEMV.

use zest::coordinator::Router;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::fmbe::FmbeConfig;
use zest::estimators::EstimatorKind;
use zest::mips::brute::BruteIndex;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::mips::MipsIndex;
use zest::util::rng::Rng;

fn store() -> zest::data::embeddings::EmbeddingStore {
    generate(&SynthConfig {
        n: 700,
        d: 24,
        clusters: 8,
        ..SynthConfig::tiny()
    })
}

/// Every estimator kind: a batch of queries through `estimate_batch`
/// must match the same queries through per-query `estimate` when the RNG
/// starts from the same seed.
#[test]
fn estimate_batch_matches_single_for_every_kind() {
    let s = store();
    let index = BruteIndex::new(&s);
    let router = Router::new(FmbeConfig {
        p_features: 300,
        ..Default::default()
    });
    let qs: Vec<Vec<f32>> = (0..9).map(|i| s.row(i * 70 + 3).to_vec()).collect();
    let (k, l) = (50, 40);
    for kind in EstimatorKind::all() {
        let singles: Vec<f64> = {
            let mut rng = Rng::seeded(123);
            qs.iter()
                .map(|q| router.estimate(*kind, k, l, &s, &index, 0, q, &mut rng))
                .collect()
        };
        let mut rng = Rng::seeded(123);
        let batched = router.estimate_batch(*kind, k, l, &s, &index, 0, &qs, &mut rng);
        assert_eq!(batched.len(), qs.len(), "{kind}");
        for (qi, (a, b)) in singles.iter().zip(&batched).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs()),
                "{kind} q{qi}: single {a} vs batched {b}"
            );
        }
    }
}

/// Router::estimate consumes the RNG identically per call, so a fresh
/// seed per single call must also reproduce the batch (guards against a
/// batched implementation that interleaves draws across queries).
#[test]
fn batched_sampling_consumes_rng_in_submission_order() {
    let s = store();
    let index = BruteIndex::new(&s);
    let router = Router::new(FmbeConfig::default());
    let qs: Vec<Vec<f32>> = (0..4).map(|i| s.row(600 + i * 20).to_vec()).collect();
    let mut rng = Rng::seeded(9);
    let a = router.estimate_batch(EstimatorKind::Mimps, 30, 30, &s, &index, 0, &qs, &mut rng);
    let mut rng = Rng::seeded(9);
    let b = router.estimate_batch(EstimatorKind::Mimps, 30, 30, &s, &index, 0, &qs, &mut rng);
    assert_eq!(a, b, "batched estimation is deterministic given the seed");
}

/// BruteIndex::top_k_batch must return the same hits as per-query top_k.
#[test]
fn brute_top_k_batch_matches_single() {
    let s = store();
    let index = BruteIndex::new(&s);
    let qs: Vec<Vec<f32>> = (0..7).map(|i| s.row(i * 90 + 1).to_vec()).collect();
    let batched = index.top_k_batch(&qs, 20);
    assert_eq!(batched.len(), qs.len());
    for (q, hits) in qs.iter().zip(&batched) {
        let want = index.top_k(q, 20);
        assert_eq!(hits.len(), want.len());
        for (h, w) in hits.iter().zip(&want) {
            assert_eq!(h.idx, w.idx, "membership must match");
            assert!(
                (h.score - w.score).abs() <= 1e-4 * (1.0 + w.score.abs()),
                "score {} vs {}",
                h.score,
                w.score
            );
        }
    }
    assert!(index.top_k_batch(&[], 5).is_empty());
}

/// KMeansTreeIndex::top_k_batch is a parallel fan-out of the identical
/// per-query traversal, so results must be exactly equal.
#[test]
fn tree_top_k_batch_matches_single_exactly() {
    let s = store();
    let tree = KMeansTreeIndex::build(
        &s,
        KMeansTreeConfig {
            max_probes: 400,
            ..Default::default()
        },
    );
    let qs: Vec<Vec<f32>> = (0..6).map(|i| s.row(i * 100 + 7).to_vec()).collect();
    let batched = tree.top_k_batch(&qs, 10);
    for (q, hits) in qs.iter().zip(&batched) {
        assert_eq!(hits, &tree.top_k(q, 10));
    }
}

/// Batched exact partition must agree with the single-query fused kernel.
#[test]
fn partition_batch_matches_single() {
    let s = store();
    let index = BruteIndex::new(&s);
    let qs: Vec<Vec<f32>> = (0..11).map(|i| s.row(i * 60 + 5).to_vec()).collect();
    let batched = index.partition_batch(&qs);
    assert_eq!(batched.len(), qs.len());
    for (q, zb) in qs.iter().zip(&batched) {
        let zs = index.partition(q);
        assert!(
            (zb - zs).abs() <= 1e-6 * zs,
            "batched {zb} vs single {zs}"
        );
    }
    assert!(index.partition_batch(&[]).is_empty());
}

/// Multi-threaded and single-threaded batched scoring agree (the
/// par_row_chunks_mut split must not change any row's result).
#[test]
fn partition_batch_thread_count_invariant() {
    let s = store();
    let a = BruteIndex::with_threads(&s, 1);
    let b = BruteIndex::with_threads(&s, 8);
    let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 123).to_vec()).collect();
    let za = a.partition_batch(&qs);
    let zb = b.partition_batch(&qs);
    for (x, y) in za.iter().zip(&zb) {
        assert!((x - y).abs() <= 1e-9 * x.abs(), "{x} vs {y}");
    }
}

/// The default-trait batch path (an index with no override) still works
/// through the whole estimator stack.
#[test]
fn default_top_k_batch_loops_correctly() {
    struct Wrap(BruteIndex);
    impl MipsIndex for Wrap {
        fn top_k(&self, q: &[f32], k: usize) -> Vec<zest::mips::Hit> {
            self.0.top_k(q, k)
        }
        fn len(&self) -> usize {
            self.0.len()
        }
        fn probe_cost(&self, k: usize) -> usize {
            self.0.probe_cost(k)
        }
        fn name(&self) -> &'static str {
            "wrapped-brute"
        }
    }
    let s = store();
    let wrapped = Wrap(BruteIndex::new(&s));
    let qs: Vec<Vec<f32>> = (0..3).map(|i| s.row(i * 31).to_vec()).collect();
    let batched = wrapped.top_k_batch(&qs, 8);
    for (q, hits) in qs.iter().zip(&batched) {
        assert_eq!(hits, &wrapped.top_k(q, 8));
    }
}
