//! `zest-top` — a terminal dashboard over the `GetMetrics` wire op.
//!
//! Polls a running `zest-server` (any mode) for its merged
//! [`zest::obs::MetricsBlob`] and renders counters as per-interval
//! rates next to the histogram percentiles, like `top` for a partition
//! server:
//!
//! ```bash
//! cargo run --release --example zest_top -- \
//!     --server unix:///tmp/zest.sock --interval-ms 1000
//! # a fixed number of refreshes (handy under a script):
//! cargo run --release --example zest_top -- \
//!     --server tcp://127.0.0.1:7070 --iterations 5
//! ```
//!
//! The same blob backs `--metrics-listen` (Prometheus text); this
//! example speaks the binary wire op instead so it works on UDS-only
//! deployments with nothing else installed.

use std::sync::Arc;
use zest::net::client::{ClientConfig, PartitionClient};
use zest::net::Addr;
use zest::obs::MetricsBlob;
use zest::util::cli::Args;

/// Counters worth a rate column, in display order.
const RATE_COUNTERS: &[&str] = &[
    "submitted",
    "completed",
    "cache_hits",
    "coalesced",
    "shed",
    "backend_errors",
    "net_frames_in",
    "net_frames_out",
];

fn main() {
    zest::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(argv).map_err(anyhow::Error::msg)?;
    args.check_known(&["server", "interval-ms", "iterations"])
        .map_err(anyhow::Error::msg)?;
    let server: String = args.require("server").map_err(anyhow::Error::msg)?;
    let interval = std::time::Duration::from_millis(args.get_or("interval-ms", 1000u64));
    // 0 = run until interrupted.
    let iterations: u64 = args.get_or("iterations", 0);

    let addr = Addr::parse(&server)?;
    let client = Arc::new(PartitionClient::connect(addr, ClientConfig::default())?);

    let mut prev: Option<MetricsBlob> = None;
    let mut round = 0u64;
    loop {
        let blob = client
            .get_metrics()
            .map_err(|e| anyhow::anyhow!("scrape failed: {e}"))?;
        render(&blob, prev.as_ref(), interval);
        prev = Some(blob);
        round += 1;
        if iterations > 0 && round >= iterations {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// One refresh: counter totals + per-interval deltas, then latency
/// percentiles for every histogram the server reports.
fn render(blob: &MetricsBlob, prev: Option<&MetricsBlob>, interval: std::time::Duration) {
    println!("── zest-top ──────────────────────────────────────────");
    println!("{:<18} {:>12} {:>12}", "counter", "total", "per-sec");
    let secs = interval.as_secs_f64().max(1e-9);
    for name in RATE_COUNTERS {
        let total = blob.counter(name);
        let delta = total.saturating_sub(prev.map_or(total, |p| p.counter(name)));
        println!(
            "{name:<18} {total:>12} {:>12.1}",
            if prev.is_some() { delta as f64 / secs } else { 0.0 }
        );
    }
    println!("{:<18} {:>10} {:>10} {:>10} {:>8}", "latency", "p50", "p99", "p999", "count");
    for (name, h) in &blob.hists {
        if h.count == 0 {
            continue;
        }
        println!(
            "{name:<18} {:>10} {:>10} {:>10} {:>8}",
            fmt_ns(h.quantile(0.5)),
            fmt_ns(h.quantile(0.99)),
            fmt_ns(h.quantile(0.999)),
            h.count
        );
    }
}

/// Nanoseconds, humanized to the nearest sensible unit.
fn fmt_ns(ns: u64) -> String {
    match ns {
        0..=9_999 => format!("{ns}ns"),
        10_000..=9_999_999 => format!("{}µs", ns / 1_000),
        10_000_000..=9_999_999_999 => format!("{}ms", ns / 1_000_000),
        _ => format!("{:.1}s", ns as f64 / 1e9),
    }
}
