//! Table 1 bench: μ/σ error grid over Uniform / MIMPS / MINCE × l, plus
//! the FMBE text numbers. Paper shape: MIMPS(k=1000,l=1000) ≈ 0.8%,
//! Uniform ≈ 100%, MINCE 10²–10⁵% worsening with k at l=1000, FMBE ~84%.

mod bench_common;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    println!(
        "== Table 1 (scale={}, N={}, d={}, queries={}, seeds={}) ==",
        env.scale, env.cfg.n, env.cfg.d, env.cfg.queries, env.cfg.seeds
    );
    // FMBE feature counts: the paper sweeps D ∈ {10k, 50k}. The FMBE fit
    // is the one O(D·N·d) build in the table — on a single-core testbed
    // the paper-scale run records D = 10k only (D = 50k is covered at
    // mid scale); override with ZEST_FMBE_DS=10000,50000.
    let fmbe_ds: Vec<usize> = std::env::var("ZEST_FMBE_DS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| match env.scale.as_str() {
            "paper" => vec![10_000],
            "mid" => vec![10_000, 50_000],
            _ => vec![1_000, 5_000],
        });
    let t0 = std::time::Instant::now();
    let t = zest::experiments::table1::run(&store, &env.cfg, &fmbe_ds);
    print!("{}", zest::experiments::table1::render(&t));
    println!("(wall: {:?})", t0.elapsed());
    bench_common::write_json(&env, "table1", &zest::experiments::table1::to_json(&t));
}
