//! Hot-path micro/macro benchmarks for the §Perf pass:
//!
//! * SIMD-vs-scalar kernel comparison (blocked GEMV / multi-query GEMM)
//!   at the detected backend,
//! * brute-force partition throughput (the O(N·d) baseline),
//! * **batched vs single-query** brute partition over a 64-query block —
//!   the tentpole comparison for the batched scoring engine,
//! * batched vs single top-k retrieval,
//! * a **shard-count sweep** (S ∈ {1,2,4,8}) over the sharded store —
//!   batched exact + scatter-gather top-k per shard count, written to
//!   `BENCH_shard_sweep.json`,
//! * MIMPS end-to-end latency through the k-means tree,
//! * PJRT chunked scoring (artifact path) vs native linalg,
//! * service round-trip overhead and batched service throughput.
//!
//! Writes the headline numbers to `BENCH_perf_hotpath.json` (package
//! root) and the full record to `results/perf_hotpath_<scale>.json`.

mod bench_common;

use std::sync::Arc;
use zest::bench::harness::time;
use zest::coordinator::{EstimateSpec, PartitionService, Router, ServiceConfig};
use zest::estimators::{mimps::Mimps, EstimateContext, Estimator, EstimatorKind};
use zest::linalg;
use zest::mips::brute::BruteIndex;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::mips::MipsIndex;
use zest::runtime::HostTensor;
use zest::util::json::Json;
use zest::util::rng::Rng;

const BATCH: usize = 64;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    let n = store.len();
    let d = store.dim();
    println!(
        "== perf_hotpath (scale={}, N={n}, d={d}, backend={}) ==",
        env.scale,
        linalg::backend()
    );
    let mut rng = Rng::seeded(7);
    let queries: Vec<Vec<f32>> = (0..BATCH)
        .map(|i| store.row(i * (n / BATCH)).to_vec())
        .collect();
    let mut record: Vec<(&str, Json)> = vec![
        ("scale", Json::str(&env.scale)),
        ("n", Json::num(n as f64)),
        ("d", Json::num(d as f64)),
        ("batch", Json::num(BATCH as f64)),
        ("backend", Json::str(&linalg::backend().to_string())),
        (
            "threads",
            Json::num(zest::util::threadpool::default_threads() as f64),
        ),
    ];

    // 0. SIMD-vs-scalar kernels on one cache-warm chunk. On non-AVX2
    //    hosts both paths run the scalar code and the ratio is ~1.
    let rows = 4096.min(n);
    let chunk = store.rows(0, rows);
    let q0 = queries[0].clone();
    let mut out = vec![0f32; rows];
    let t_gemv = time(3, 50, || {
        linalg::gemv_blocked(chunk, rows, d, &q0, &mut out);
        std::hint::black_box(&out);
    });
    let t_gemv_scalar = time(3, 50, || {
        linalg::scalar::gemv_blocked(chunk, rows, d, &q0, &mut out);
        std::hint::black_box(&out);
    });
    println!("gemv dispatch   : {t_gemv}");
    println!(
        "gemv scalar     : {t_gemv_scalar}  => simd speedup {:.2}x",
        t_gemv_scalar.mean_secs() / t_gemv.mean_secs()
    );
    let nq_tile = 16;
    let mut qs_flat = Vec::with_capacity(nq_tile * d);
    for q in queries.iter().take(nq_tile) {
        qs_flat.extend_from_slice(q);
    }
    let mut gout = vec![0f32; rows * nq_tile];
    let t_gemm = time(2, 20, || {
        linalg::gemm(chunk, rows, d, &qs_flat, nq_tile, &mut gout);
        std::hint::black_box(&gout);
    });
    let t_gemm_scalar = time(2, 20, || {
        linalg::scalar::gemm(chunk, rows, d, &qs_flat, nq_tile, &mut gout);
        std::hint::black_box(&gout);
    });
    println!("gemm({nq_tile}q) dispatch: {t_gemm}");
    println!(
        "gemm({nq_tile}q) scalar  : {t_gemm_scalar}  => simd speedup {:.2}x",
        t_gemm_scalar.mean_secs() / t_gemm.mean_secs()
    );
    // Per-query cost inside the GEMM: each streamed row is amortized
    // over the whole query tile.
    println!(
        "gemm per-query  : {:.1}% of one gemv pass",
        100.0 * t_gemm.mean_secs() / nq_tile as f64 / t_gemv.mean_secs()
    );
    record.push(("gemv_dispatch_s", Json::num(t_gemv.mean_secs())));
    record.push(("gemv_scalar_s", Json::num(t_gemv_scalar.mean_secs())));
    record.push(("gemm_dispatch_s", Json::num(t_gemm.mean_secs())));
    record.push(("gemm_scalar_s", Json::num(t_gemm_scalar.mean_secs())));

    // 1. Brute-force partition (multithreaded).
    let brute = BruteIndex::new(&store);
    let mut qi = 0;
    let t = time(3, 30, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(brute.partition(q));
    });
    let flops = 2.0 * n as f64 * d as f64;
    println!(
        "brute partition : {t}  ({:.2} GFLOP/s effective)",
        flops / t.mean_secs() / 1e9
    );
    record.push(("brute_partition_s", Json::num(t.mean_secs())));

    // 1b. Batched vs single-query partition over the 64-query block: the
    //     single path re-streams the N×d matrix once per query; the
    //     batched path streams it once per *batch* through the 4×4 GEMM
    //     micro-kernel. This is the tentpole number (target ≥ 2x).
    let t_single64 = time(1, 5, || {
        for q in &queries {
            std::hint::black_box(brute.partition(q));
        }
    });
    let t_batch64 = time(1, 5, || {
        std::hint::black_box(brute.partition_batch(&queries));
    });
    let batched_speedup = t_single64.mean_secs() / t_batch64.mean_secs();
    println!("partition x{BATCH} single : {t_single64}");
    println!(
        "partition x{BATCH} batched: {t_batch64}  => batched speedup {batched_speedup:.2}x \
         ({:.0} q/s)",
        BATCH as f64 / t_batch64.mean_secs()
    );
    record.push(("partition_single64_s", Json::num(t_single64.mean_secs())));
    record.push(("partition_batch64_s", Json::num(t_batch64.mean_secs())));
    record.push(("batched_speedup", Json::num(batched_speedup)));
    record.push((
        "batched_qps",
        Json::num(BATCH as f64 / t_batch64.mean_secs()),
    ));

    // 1c. Batched top-k retrieval (one GEMM scoring pass + per-query
    //     selection) vs a per-query loop.
    let t_topk_single = time(1, 3, || {
        for q in &queries {
            std::hint::black_box(brute.top_k(q, 100));
        }
    });
    let t_topk_batch = time(1, 3, || {
        std::hint::black_box(brute.top_k_batch(&queries, 100));
    });
    println!("top-100 x{BATCH} single : {t_topk_single}");
    println!(
        "top-100 x{BATCH} batched: {t_topk_batch}  => speedup {:.2}x",
        t_topk_single.mean_secs() / t_topk_batch.mean_secs()
    );
    record.push(("topk_single64_s", Json::num(t_topk_single.mean_secs())));
    record.push(("topk_batch64_s", Json::num(t_topk_batch.mean_secs())));

    // 1d. Shard-count sweep over the epoch-snapshotted sharded store:
    //     batched exact partition (bit-identical streaming across
    //     shards) and batched top-100 through the scatter-gather
    //     ShardedIndex, S ∈ {1, 2, 4, 8}. Written to its own
    //     BENCH_shard_sweep.json so the CI artifact trail accumulates a
    //     sharding trajectory alongside the hot-path one.
    {
        use zest::estimators::exact::Exact;
        use zest::mips::sharded::ShardedIndex;
        use zest::store::ShardedStore;
        let mut rows_json: Vec<Json> = Vec::new();
        let mut base_exact = 0f64;
        let mut base_topk = 0f64;
        for s in [1usize, 2, 4, 8] {
            let sharded = ShardedStore::split(&store, s);
            let index = ShardedIndex::brute(&sharded);
            let t_exact = time(1, 3, || {
                let mut ctx = EstimateContext::new(&sharded, &index, &mut rng);
                std::hint::black_box(Exact.estimate_batch(&mut ctx, &queries));
            });
            let t_topk = time(1, 3, || {
                std::hint::black_box(index.top_k_batch(&queries, 100));
            });
            if s == 1 {
                base_exact = t_exact.mean_secs();
                base_topk = t_topk.mean_secs();
            }
            println!(
                "shards={s}: exact x{BATCH} {t_exact}  top-100 x{BATCH} {t_topk}  \
                 (vs S=1: exact {:.2}x, topk {:.2}x)",
                base_exact / t_exact.mean_secs(),
                base_topk / t_topk.mean_secs()
            );
            rows_json.push(Json::obj(vec![
                ("shards", Json::num(s as f64)),
                ("exact_batch64_s", Json::num(t_exact.mean_secs())),
                ("topk_batch64_s", Json::num(t_topk.mean_secs())),
            ]));
        }
        let sweep = Json::obj(vec![
            ("scale", Json::str(&env.scale)),
            ("n", Json::num(n as f64)),
            ("d", Json::num(d as f64)),
            ("batch", Json::num(BATCH as f64)),
            ("backend", Json::str(&linalg::backend().to_string())),
            ("rows", Json::Arr(rows_json)),
        ]);
        std::fs::write("BENCH_shard_sweep.json", sweep.to_string()).ok();
        println!("(json: BENCH_shard_sweep.json)");
        bench_common::write_json(&env, "shard_sweep", &sweep);
    }

    // 2. Tree search alone (k=100, default probes).
    let tree = KMeansTreeIndex::build(&store, KMeansTreeConfig::default());
    let mut qi = 0;
    let t = time(3, 100, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(tree.top_k(q, 100));
    });
    println!("tree top-100    : {t}");

    // 3. MIMPS end-to-end through the tree: single loop vs estimate_batch.
    let est = Mimps::new(100, 100);
    let mut qi = 0;
    let t_mips = time(3, 100, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        let mut ctx = EstimateContext::new(&store, &tree, &mut rng);
        std::hint::black_box(est.estimate(&mut ctx, q));
    });
    println!("MIMPS(100,100)  : {t_mips}");
    let t_mips_batch = time(1, 5, || {
        let mut ctx = EstimateContext::new(&store, &tree, &mut rng);
        std::hint::black_box(est.estimate_batch(&mut ctx, &queries));
    });
    println!(
        "MIMPS x{BATCH} batched : {t_mips_batch}  => {:.2}x vs single loop",
        t_mips.mean_secs() * BATCH as f64 / t_mips_batch.mean_secs()
    );
    record.push(("mimps_single_s", Json::num(t_mips.mean_secs())));
    record.push(("mimps_batch64_s", Json::num(t_mips_batch.mean_secs())));

    // 4. Single-thread brute (per-query latency basis for speedup).
    let brute1 = BruteIndex::with_threads(&store, 1);
    let mut qi = 0;
    let t_brute1 = time(1, 10, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(brute1.partition(q));
    });
    println!(
        "brute 1-thread  : {t_brute1}  => single-query speedup {:.1}x",
        t_brute1.mean_secs() / t_mips.mean_secs()
    );

    // 5. PJRT artifact scoring vs native, when artifacts exist.
    let dir = std::path::PathBuf::from(&env.cfg.artifacts_dir);
    if dir.join("meta.json").exists() {
        if let Ok(meta) = zest::runtime::ArtifactsMeta::load(&dir) {
            let chunk = meta.config_usize("chunk").unwrap_or(8192);
            let da = meta.config_usize("d").unwrap_or(300);
            if da == d && n >= chunk {
                let (rt, join) = zest::runtime::spawn_runtime_thread(
                    dir.clone(),
                    Some(vec!["partition_chunk".into()]),
                )
                .expect("runtime");
                let v = store.rows(0, chunk).to_vec();
                let q = queries[0].clone();
                let t = time(2, 20, || {
                    let out = rt
                        .run(
                            "partition_chunk",
                            vec![
                                HostTensor::f32(v.clone(), &[chunk, d]),
                                HostTensor::f32(q.clone(), &[d]),
                            ],
                        )
                        .unwrap();
                    std::hint::black_box(out[0].first_f64());
                });
                println!("pjrt chunk({chunk}) : {t}");
                let t = time(2, 20, || {
                    let mut s = vec![0f32; chunk];
                    zest::linalg::gemv_blocked(&v, chunk, d, &q, &mut s);
                    std::hint::black_box(zest::linalg::sum_exp(&s));
                });
                println!("native chunk    : {t}");
                rt.shutdown();
                join.join().ok();
            } else {
                println!("pjrt chunk      : skipped (artifact d={da} != store d={d})");
            }
        }
    }

    // 6. Service: round-trip latency, then batched throughput under a
    //    concurrent flood (the batcher drains bursts into estimate_batch).
    let store_arc = Arc::new(store);
    let index: Arc<dyn MipsIndex> =
        Arc::new(KMeansTreeIndex::build(&store_arc, KMeansTreeConfig::default()));
    let svc = PartitionService::start(
        store_arc.clone(),
        index,
        Router::new(Default::default()),
        ServiceConfig::default(),
        None,
    );
    let mut qi = 0;
    let t_svc = time(3, 100, || {
        let q = queries[qi % queries.len()].clone();
        qi += 1;
        std::hint::black_box(
            svc.estimate(EstimateSpec::new(q).kind(EstimatorKind::Mimps).k(100).l(100))
                .unwrap(),
        );
    });
    println!(
        "service rtt     : {t_svc}  (overhead vs direct: {:.0}%)",
        100.0 * (t_svc.mean_secs() - t_mips.mean_secs()) / t_mips.mean_secs()
    );
    let flood = 512usize;
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = (0..flood)
        .map(|i| {
            svc.submit(
                EstimateSpec::new(queries[i % queries.len()].clone())
                    .kind(EstimatorKind::Mimps)
                    .k(100)
                    .l(100),
            )
            .unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap();
    }
    let flood_secs = t0.elapsed().as_secs_f64();
    let svc_qps = flood as f64 / flood_secs.max(1e-12);
    println!("service flood   : {flood} reqs in {flood_secs:.3}s => {svc_qps:.0} q/s");
    let m = svc.metrics();
    println!("{m}");
    record.push(("service_rtt_s", Json::num(t_svc.mean_secs())));
    record.push(("service_flood_qps", Json::num(svc_qps)));
    record.push(("service_mean_batch", Json::num(m.mean_batch_size)));
    record.push(("service_batch_rps", Json::num(m.batch_throughput_rps)));
    svc.shutdown();

    let json = Json::obj(record);
    std::fs::write("BENCH_perf_hotpath.json", json.to_string()).ok();
    println!("(json: BENCH_perf_hotpath.json)");
    bench_common::write_json(&env, "perf_hotpath", &json);
}
