//! Hot-path micro/macro benchmarks for the §Perf pass:
//!
//! * brute-force partition throughput (the O(N·d) baseline),
//! * MIMPS end-to-end latency through the k-means tree,
//! * tree search alone,
//! * PJRT chunked scoring (artifact path) vs native linalg,
//! * service round-trip overhead vs direct estimator call.

mod bench_common;

use std::sync::Arc;
use zest::bench::harness::time;
use zest::coordinator::{PartitionService, Request, Router, ServiceConfig};
use zest::estimators::{mimps::Mimps, EstimateContext, Estimator, EstimatorKind};
use zest::mips::brute::BruteIndex;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::mips::MipsIndex;
use zest::runtime::HostTensor;
use zest::util::rng::Rng;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    let n = store.len();
    let d = store.dim();
    println!("== perf_hotpath (scale={}, N={n}, d={d}) ==", env.scale);
    let mut rng = Rng::seeded(7);
    let queries: Vec<Vec<f32>> = (0..64).map(|i| store.row(i * (n / 64)).to_vec()).collect();

    // 1. Brute-force partition (multithreaded).
    let brute = BruteIndex::new(&store);
    let mut qi = 0;
    let t = time(3, 30, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(brute.partition(q));
    });
    let flops = 2.0 * n as f64 * d as f64;
    println!(
        "brute partition : {t}  ({:.2} GFLOP/s effective)",
        flops / t.mean_secs() / 1e9
    );

    // 2. Tree search alone (k=100, default probes).
    let tree = KMeansTreeIndex::build(&store, KMeansTreeConfig::default());
    let mut qi = 0;
    let t = time(3, 100, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(tree.top_k(q, 100));
    });
    println!("tree top-100    : {t}");

    // 3. MIMPS end-to-end through the tree.
    let est = Mimps::new(100, 100);
    let mut qi = 0;
    let t_mips = time(3, 100, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        let mut ctx = EstimateContext {
            store: &store,
            index: &tree,
            rng: &mut rng,
        };
        std::hint::black_box(est.estimate(&mut ctx, q));
    });
    println!("MIMPS(100,100)  : {t_mips}");

    // 4. Single-thread brute (per-query latency basis for speedup).
    let brute1 = BruteIndex::with_threads(&store, 1);
    let mut qi = 0;
    let t_brute1 = time(1, 10, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(brute1.partition(q));
    });
    println!(
        "brute 1-thread  : {t_brute1}  => single-query speedup {:.1}x",
        t_brute1.mean_secs() / t_mips.mean_secs()
    );

    // 5. PJRT artifact scoring vs native, when artifacts exist.
    let dir = std::path::PathBuf::from(&env.cfg.artifacts_dir);
    if dir.join("meta.json").exists() {
        if let Ok(meta) = zest::runtime::ArtifactsMeta::load(&dir) {
            let chunk = meta.config_usize("chunk").unwrap_or(8192);
            let da = meta.config_usize("d").unwrap_or(300);
            if da == d && n >= chunk {
                let (rt, join) = zest::runtime::spawn_runtime_thread(
                    dir.clone(),
                    Some(vec!["partition_chunk".into()]),
                )
                .expect("runtime");
                let v = store.rows(0, chunk).to_vec();
                let q = queries[0].clone();
                let t = time(2, 20, || {
                    let out = rt
                        .run(
                            "partition_chunk",
                            vec![
                                HostTensor::f32(v.clone(), &[chunk, d]),
                                HostTensor::f32(q.clone(), &[d]),
                            ],
                        )
                        .unwrap();
                    std::hint::black_box(out[0].first_f64());
                });
                println!("pjrt chunk({chunk}) : {t}");
                let t = time(2, 20, || {
                    let mut s = vec![0f32; chunk];
                    zest::linalg::gemv_blocked(&v, chunk, d, &q, &mut s);
                    std::hint::black_box(zest::linalg::sum_exp(&s));
                });
                println!("native chunk    : {t}");
                rt.shutdown();
                join.join().ok();
            } else {
                println!("pjrt chunk      : skipped (artifact d={da} != store d={d})");
            }
        }
    }

    // 6. Service round-trip overhead.
    let store_arc = Arc::new(store);
    let index: Arc<dyn MipsIndex> =
        Arc::new(KMeansTreeIndex::build(&store_arc, KMeansTreeConfig::default()));
    let svc = PartitionService::start(
        store_arc.clone(),
        index,
        Router::new(Default::default()),
        ServiceConfig::default(),
        None,
    );
    let mut qi = 0;
    let t_svc = time(3, 100, || {
        let q = queries[qi % queries.len()].clone();
        qi += 1;
        std::hint::black_box(
            svc.estimate(Request {
                query: q,
                kind: EstimatorKind::Mimps,
                k: 100,
                l: 100,
            })
            .unwrap(),
        );
    });
    println!(
        "service rtt     : {t_svc}  (overhead vs direct: {:.0}%)",
        100.0 * (t_svc.mean_secs() - t_mips.mean_secs()) / t_mips.mean_secs()
    );
    println!("{}", svc.metrics());
    svc.shutdown();
}
