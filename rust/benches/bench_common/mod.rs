#![allow(dead_code)]
//! Shared scaffolding for the `cargo bench` targets.
//!
//! Scale control: `ZEST_SCALE=paper` runs the paper's dimensions
//! (N = 100k, d = 300); the default `quick` scale keeps every bench under
//! a couple of minutes while preserving the qualitative shape. Both use
//! 3 seeds like the paper. Query counts default to 1000 (paper: 10k) —
//! raise with `ZEST_QUERIES`.

use zest::config::Config;
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};

pub struct BenchEnv {
    pub cfg: Config,
    pub synth: SynthConfig,
    pub scale: String,
}

pub fn env() -> BenchEnv {
    zest::util::logging::init();
    let scale = std::env::var("ZEST_SCALE").unwrap_or_else(|_| "quick".to_string());
    let (n, d) = match scale.as_str() {
        "paper" => (100_000, 300),
        "mid" => (30_000, 128),
        // CI smoke scale: small enough for a shared runner, big enough
        // that the batched-vs-single comparison is still meaningful.
        "small" => (2_000, 32),
        _ => (10_000, 64),
    };
    let queries = std::env::var("ZEST_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000usize);
    let cfg = Config {
        n,
        d,
        queries,
        seeds: 3,
        out_dir: "results".to_string(),
        ..Config::default()
    };
    let synth = SynthConfig {
        n,
        d,
        seed: cfg.seed,
        ..Default::default()
    };
    BenchEnv { cfg, synth, scale }
}

/// Generate or load the cached store for the bench scale.
pub fn store(env: &BenchEnv) -> EmbeddingStore {
    let dir = std::path::PathBuf::from(&env.cfg.out_dir);
    std::fs::create_dir_all(&dir).ok();
    let cache = dir.join(format!(
        "emb_n{}_d{}_s{}.bin",
        env.cfg.n, env.cfg.d, env.cfg.seed
    ));
    if cache.exists() {
        if let Ok(s) = EmbeddingStore::load(&cache) {
            return s;
        }
    }
    let s = generate(&env.synth);
    s.save(&cache).ok();
    s
}

pub fn write_json(env: &BenchEnv, name: &str, json: &zest::util::json::Json) {
    let dir = std::path::PathBuf::from(&env.cfg.out_dir);
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{name}_{}.json", env.scale));
    std::fs::write(&path, json.to_string()).ok();
    println!("(json: {})", path.display());
}
