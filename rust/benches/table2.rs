//! Table 2 bench: error vs query-noise level (0/10/20/30% relative norm).
//! Paper shape: MIMPS flat (0.8 → 0.9), Uniform ~100%+, MINCE bad
//! throughout, FMBE ~84–87%.

mod bench_common;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    let mut cfg = env.cfg.clone();
    // Paper caption: k = l = 1000 for MIMPS (clamped on small scales).
    cfg.k = 1000.min(store.len() / 2);
    cfg.l = 1000.min(store.len() / 2);
    println!(
        "== Table 2 (scale={}, N={}, d={}, queries={}, k={}, l={}) ==",
        env.scale, cfg.n, cfg.d, cfg.queries, cfg.k, cfg.l
    );
    // One FMBE fit is shared across all noise levels; at paper scale on a
    // single core D = 10k keeps the fit tractable (paper caption: 50k).
    let fmbe_d = match env.scale.as_str() {
        "paper" => 10_000,
        "mid" => 50_000,
        _ => 5_000,
    };
    let t0 = std::time::Instant::now();
    let t = zest::experiments::table2::run(&store, &cfg, fmbe_d);
    print!("{}", zest::experiments::table2::render(&t));
    println!("(wall: {:?})", t0.elapsed());
    bench_common::write_json(&env, "table2", &zest::experiments::table2::to_json(&t));
}
