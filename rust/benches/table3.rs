//! Table 3 bench: error under injected retrieval errors (drop rank-1 /
//! rank-2 / both). Paper shape: MIMPS 0.8 → 39.3 (drop 1) / 6.1 (drop 2)
//! / 45.0 (both); MINCE flat at its (bad) level.

mod bench_common;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    let mut cfg = env.cfg.clone();
    cfg.k = 1000.min(store.len() / 2);
    cfg.l = 1000.min(store.len() / 2);
    println!(
        "== Table 3 (scale={}, N={}, d={}, queries={}, k={}, l={}) ==",
        env.scale, cfg.n, cfg.d, cfg.queries, cfg.k, cfg.l
    );
    let t0 = std::time::Instant::now();
    let t = zest::experiments::table3::run(&store, &cfg);
    print!("{}", zest::experiments::table3::render(&t));
    println!("(wall: {:?})", t0.elapsed());
    bench_common::write_json(&env, "table3", &zest::experiments::table3::to_json(&t));
}
