//! Ablation bench: Halley vs Newton solver, index families, and MIMPS
//! error vs tree probe budget (DESIGN.md §Testing / §Perf design calls).

mod bench_common;

use zest::experiments::ablations::*;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    println!(
        "== Ablations (scale={}, N={}, d={}) ==",
        env.scale, env.cfg.n, env.cfg.d
    );

    let solver = solver_ablation(500, 1000.min(env.cfg.n / 2), 1000.min(env.cfg.n / 2), 0);
    println!(
        "solver: Newton {} iters {:?} | Halley {} iters {:?} | max disagreement {:.2e}",
        solver.newton_iters,
        solver.newton_wall,
        solver.halley_iters,
        solver.halley_wall,
        solver.max_disagreement
    );

    let index = index_ablation(&store, 30, env.cfg.seed);
    for r in &index {
        println!(
            "index {:<12} recall@10={:.3} top1={:.3} probes={:.0} build={:?}",
            r.name, r.recall_at_10, r.top1_recall, r.mean_probes, r.build_wall
        );
    }

    let mut cfg = env.cfg.clone();
    cfg.queries = cfg.queries.min(200);
    cfg.k = 100;
    cfg.l = 100;
    let budgets = [256usize, 1024, 4096, 16384]
        .iter()
        .copied()
        .filter(|&b| b <= cfg.n)
        .collect::<Vec<_>>();
    let pts = probe_budget_ablation(&store, &cfg, &budgets);
    for p in &pts {
        println!("probes={:<8} MIMPS(k=100,l=100) err={:.2}%", p.probes, p.mean_err_pct);
    }
    bench_common::write_json(&env, "ablations", &to_json(&solver, &index, &pts));
}
