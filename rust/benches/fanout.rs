//! Parallel-vs-sequential publish fan-out benchmark (§Serving in
//! `EXPERIMENTS.md`).
//!
//! Spawns S in-process shard-worker servers over loopback TCP whose
//! handlers sleep a fixed `DELAY` on every publish op (emulating the
//! per-op network + staging latency a real worker would add), then
//! measures a cluster-wide epoch publish (pure epoch bump) two ways:
//!
//! * **sequential** — an explicit prepare-then-commit loop over raw
//!   `RemoteShard` handles: what `RemoteCluster::publish` did before
//!   the per-worker I/O-slot fan-out (Σ-over-workers latency);
//! * **parallel** — `RemoteCluster::remove_categories(&[])` through the
//!   fan-out path (max-over-workers latency).
//!
//! With per-op delay δ the model cost is ≈ `2·S·δ` sequential vs
//! ≈ `2·δ` parallel, so the speedup approaches S. Writes the headline
//! rows to `BENCH_fanout.json` (package root) and the full record to
//! `results/fanout_<scale>.json`.

mod bench_common;

use std::sync::Arc;
use std::time::{Duration, Instant};
use zest::bench::harness::Table;
use zest::coordinator::ServiceMetrics;
use zest::data::synth::{generate, SynthConfig};
use zest::net::client::ClientConfig;
use zest::net::remote::{aligned_split, RemoteCluster, RemoteShard};
use zest::net::server::{Handler, Server, ServerConfig};
use zest::net::shard::ShardWorker;
use zest::net::{wire, Addr};
use zest::util::json::Json;

/// Emulated per-op worker latency on the publish path.
const DELAY: Duration = Duration::from_millis(3);
/// Publishes per measurement (averaged).
const REPS: usize = 5;

/// Wraps a [`ShardWorker`], sleeping [`DELAY`] on every publish op and
/// every exp-sum op (emulating per-op network + compute latency, so the
/// chained-vs-pipelined `Exact` comparison below sees the same worker
/// cost model as the publish comparison).
struct SlowPublish {
    inner: ShardWorker,
}

impl Handler for SlowPublish {
    fn handle(&self, req: wire::Request) -> wire::Response {
        if matches!(
            req,
            wire::Request::PrepareAdd { .. }
                | wire::Request::PrepareRemove { .. }
                | wire::Request::Commit { .. }
                | wire::Request::ExpSumChain { .. }
                | wire::Request::ExpSumChainBatch { .. }
                | wire::Request::ExpSumPart { .. }
        ) {
            std::thread::sleep(DELAY);
        }
        self.inner.handle(req)
    }
}

fn main() {
    let env = bench_common::env();
    let store = generate(&SynthConfig {
        n: 64,
        d: 8,
        ..SynthConfig::tiny()
    });
    println!(
        "== fanout (delay={}ms/op, {REPS} publishes per point) ==",
        DELAY.as_millis()
    );
    let mut table = Table::new(&[
        "workers",
        "seq publish (ms)",
        "par publish (ms)",
        "speedup",
        "chained Z (ms)",
        "pipelined Z (ms)",
        "Z speedup",
    ]);
    let mut rows_json: Vec<Json> = Vec::new();
    let queries: Vec<Vec<f32>> = (0..4).map(|i| store.row(i * 16).to_vec()).collect();

    for s in [2usize, 4, 8] {
        let mut servers = Vec::new();
        let mut addrs: Vec<Addr> = Vec::new();
        for block in aligned_split(&store, s) {
            let server = Server::serve(
                &Addr::Tcp("127.0.0.1:0".to_string()),
                Arc::new(SlowPublish {
                    inner: ShardWorker::new(block),
                }),
                ServerConfig::default(),
                Arc::new(ServiceMetrics::new()),
            )
            .expect("bind worker");
            addrs.push(server.local_addr().clone());
            servers.push(server);
        }

        // Sequential baseline: the pre-fan-out publish shape — one
        // blocking RPC per worker per phase.
        let shards: Vec<RemoteShard> = addrs
            .iter()
            .map(|a| {
                RemoteShard::connect(a.clone(), ClientConfig::default())
                    .expect("connect")
                    .0
            })
            .collect();
        let t0 = Instant::now();
        for r in 0..REPS {
            let token = 0xFA0_0000 + r as u64;
            for shard in &shards {
                shard.prepare_remove(token, &[]).expect("prepare");
            }
            for shard in &shards {
                shard.commit(token).expect("commit");
            }
        }
        let seq_s = t0.elapsed().as_secs_f64() / REPS as f64;
        drop(shards);

        // Parallel: the same pure epoch bump through the per-worker
        // I/O-slot fan-out (includes the post-publish manifest refresh).
        let cluster =
            RemoteCluster::connect(&addrs, ClientConfig::default()).expect("connect cluster");
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.remove_categories(&[]).expect("publish");
        }
        let par_s = t0.elapsed().as_secs_f64() / REPS as f64;

        // Two-mode Exact: the bit-exact chain pays S sequential delayed
        // round-trips (≈ S·δ); the pipelined ExpSumPart fan-out pays
        // the slowest worker (≈ δ) — max-over-workers latency for the
        // last-ulp cost documented in net::remote.
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.exp_sum_batch(&queries).expect("chained exp-sum");
        }
        let chain_s = t0.elapsed().as_secs_f64() / REPS as f64;
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.exp_sum_parts(&queries).expect("pipelined exp-sum");
        }
        let pipe_s = t0.elapsed().as_secs_f64() / REPS as f64;
        drop(cluster);

        let speedup = seq_s / par_s;
        let z_speedup = chain_s / pipe_s;
        println!(
            "workers={s}: publish sequential {:.2} ms vs parallel {:.2} ms => {speedup:.2}x; \
             exact chained {:.2} ms vs pipelined {:.2} ms => {z_speedup:.2}x",
            seq_s * 1e3,
            par_s * 1e3,
            chain_s * 1e3,
            pipe_s * 1e3
        );
        table.row(vec![
            s.to_string(),
            format!("{:.2}", seq_s * 1e3),
            format!("{:.2}", par_s * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.2}", chain_s * 1e3),
            format!("{:.2}", pipe_s * 1e3),
            format!("{z_speedup:.2}x"),
        ]);
        rows_json.push(Json::obj(vec![
            ("workers", Json::num(s as f64)),
            ("seq_publish_s", Json::num(seq_s)),
            ("par_publish_s", Json::num(par_s)),
            ("speedup", Json::num(speedup)),
            ("chained_expsum_s", Json::num(chain_s)),
            ("pipelined_expsum_s", Json::num(pipe_s)),
            ("expsum_speedup", Json::num(z_speedup)),
        ]));

        for server in servers {
            server.shutdown();
        }
    }

    table.print();
    let json = Json::obj(vec![
        ("delay_ms", Json::num(DELAY.as_millis() as f64)),
        ("reps", Json::num(REPS as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_fanout.json", json.to_string()).ok();
    println!("(json: BENCH_fanout.json)");
    bench_common::write_json(&env, "fanout", &json);
}
