//! Parallel-vs-sequential publish fan-out benchmark (§Serving in
//! `EXPERIMENTS.md`).
//!
//! Spawns S in-process shard-worker servers over loopback TCP whose
//! handlers sleep a fixed `DELAY` on every publish op (emulating the
//! per-op network + staging latency a real worker would add), then
//! measures a cluster-wide epoch publish (pure epoch bump) two ways:
//!
//! * **sequential** — an explicit prepare-then-commit loop over raw
//!   `RemoteShard` handles: what `RemoteCluster::publish` did before
//!   the per-worker I/O-slot fan-out (Σ-over-workers latency);
//! * **parallel** — `RemoteCluster::remove_categories(&[])` through the
//!   fan-out path (max-over-workers latency).
//!
//! With per-op delay δ the model cost is ≈ `2·S·δ` sequential vs
//! ≈ `2·δ` parallel, so the speedup approaches S. Writes the headline
//! rows to `BENCH_fanout.json` (package root) and the full record to
//! `results/fanout_<scale>.json`.
//!
//! A second, wire-v3 **connection-scale** section benchmarks the
//! reactor rewrite itself and writes `BENCH_reactor.json`:
//!
//! * **concurrent-clients sweep** — one reactor pool serving 1→256
//!   client connections, aggregate RPC throughput per point;
//! * **pipelined-RPC depth sweep** — D concurrent `ExpSumPart`
//!   scatters multiplexed on one connection per worker: per-scatter
//!   latency stays ≈ max-over-workers (δ), not Σ, at every depth > 1,
//!   because overlapped frames share the socket instead of queuing
//!   behind a one-slot pipeline.
//!
//! A third **front-door** section (§Front door in `EXPERIMENTS.md`)
//! benchmarks the epoch-keyed result cache and the single-flight
//! coalescer in front of the batcher, writing `BENCH_frontdoor.json`:
//!
//! * **repeat-rate sweep** — the same request stream under three
//!   repeat mixes (uniform over a query pool, Zipf s = 1.0, and
//!   all-identical), splitting per-request latency into cold (executed)
//!   vs warm (cache-hit) p50 — the hit path must be ≥ 10× faster on
//!   the all-identical mix;
//! * **coalesced herd** — 64 identical concurrent requests on a cold
//!   cache: single-flight makes the whole herd cost ~one execution's
//!   wall time instead of 64.
//!
//! A fourth **observability** section (§obs) guards the tracing
//! overhead, writing `BENCH_obs.json`: the same executed-request
//! workload at `trace_sample_rate` 0 / 0.01 / 1.0 — the off path must
//! cost nothing (no allocation, one sampler branch), and the ratios
//! are recorded for trend tracking rather than hard-asserted.
//!
//! A fifth **failover** section (§failover) measures the replica-set
//! layer, writing `BENCH_failover.json`: scatter-read p50/p99 with all
//! replicas healthy vs with one replica of each shard killed mid-run
//! (failover absorbing the dead picks), and the time-to-heal of the
//! `refresh()` that replays the missed publish once the replicas
//! return.
//!
//! `ZEST_FANOUT_SECTION=<fanout|reactor|frontdoor|obs|failover>` runs
//! one section alone (CI's net-smoke job extracts §failover this way).

mod bench_common;

use std::sync::Arc;
use std::time::{Duration, Instant};
use zest::bench::harness::Table;
use zest::coordinator::ServiceMetrics;
use zest::data::synth::{generate, SynthConfig};
use zest::net::client::ClientConfig;
use zest::net::remote::{aligned_split, RemoteCluster, RemoteShard};
use zest::net::server::{Handler, Server, ServerConfig};
use zest::net::shard::ShardWorker;
use zest::net::{wire, Addr};
use zest::util::json::Json;

/// Emulated per-op worker latency on the publish path.
const DELAY: Duration = Duration::from_millis(3);
/// Publishes per measurement (averaged).
const REPS: usize = 5;

/// Wraps a [`ShardWorker`], sleeping [`DELAY`] on every publish op and
/// every exp-sum op (emulating per-op network + compute latency, so the
/// chained-vs-pipelined `Exact` comparison below sees the same worker
/// cost model as the publish comparison).
struct SlowPublish {
    inner: ShardWorker,
}

impl Handler for SlowPublish {
    fn handle(&self, req: wire::Request) -> wire::Response {
        if matches!(
            req,
            wire::Request::PrepareAdd { .. }
                | wire::Request::PrepareRemove { .. }
                | wire::Request::Commit { .. }
                | wire::Request::ExpSumChain { .. }
                | wire::Request::ExpSumChainBatch { .. }
                | wire::Request::ExpSumPart { .. }
        ) {
            std::thread::sleep(DELAY);
        }
        self.inner.handle(req)
    }
}

fn main() {
    let env = bench_common::env();
    // `ZEST_FANOUT_SECTION=failover` (or fanout/reactor/frontdoor/obs)
    // runs one section alone — CI's net-smoke job uses it to produce
    // `BENCH_failover.json` without paying for the full sweep.
    let only = std::env::var("ZEST_FANOUT_SECTION").ok();
    let run = |name: &str| only.as_deref().map_or(true, |o| o == name);
    let store = generate(&SynthConfig {
        n: 64,
        d: 8,
        ..SynthConfig::tiny()
    });
    if run("fanout") {
        fanout_section(&env, &store);
    }
    if run("reactor") {
        reactor_section(&env, &store);
    }
    if run("frontdoor") {
        frontdoor_section(&env);
    }
    if run("obs") {
        obs_section(&env);
    }
    if run("failover") {
        failover_section(&env, &store);
    }
}

/// The original publish fan-out comparison (sequential vs parallel
/// publish, chained vs pipelined `Exact`). Writes `BENCH_fanout.json`.
fn fanout_section(env: &bench_common::BenchEnv, store: &zest::data::embeddings::EmbeddingStore) {
    println!(
        "== fanout (delay={}ms/op, {REPS} publishes per point) ==",
        DELAY.as_millis()
    );
    let mut table = Table::new(&[
        "workers",
        "seq publish (ms)",
        "par publish (ms)",
        "speedup",
        "chained Z (ms)",
        "pipelined Z (ms)",
        "Z speedup",
    ]);
    let mut rows_json: Vec<Json> = Vec::new();
    let queries: Vec<Vec<f32>> = (0..4).map(|i| store.row(i * 16).to_vec()).collect();

    for s in [2usize, 4, 8] {
        let mut servers = Vec::new();
        let mut addrs: Vec<Addr> = Vec::new();
        for block in aligned_split(&store, s) {
            let server = Server::serve(
                &Addr::Tcp("127.0.0.1:0".to_string()),
                Arc::new(SlowPublish {
                    inner: ShardWorker::new(block),
                }),
                ServerConfig::default(),
                Arc::new(ServiceMetrics::new()),
            )
            .expect("bind worker");
            addrs.push(server.local_addr().clone());
            servers.push(server);
        }

        // Sequential baseline: the pre-fan-out publish shape — one
        // blocking RPC per worker per phase.
        let shards: Vec<RemoteShard> = addrs
            .iter()
            .map(|a| {
                RemoteShard::connect(a.clone(), ClientConfig::default())
                    .expect("connect")
                    .0
            })
            .collect();
        let t0 = Instant::now();
        for r in 0..REPS {
            let token = 0xFA0_0000 + r as u64;
            for shard in &shards {
                shard.prepare_remove(token, &[]).expect("prepare");
            }
            for shard in &shards {
                shard.commit(token).expect("commit");
            }
        }
        let seq_s = t0.elapsed().as_secs_f64() / REPS as f64;
        drop(shards);

        // Parallel: the same pure epoch bump through the per-worker
        // I/O-slot fan-out (includes the post-publish manifest refresh).
        let cluster =
            RemoteCluster::connect(&addrs, ClientConfig::default()).expect("connect cluster");
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.remove_categories(&[]).expect("publish");
        }
        let par_s = t0.elapsed().as_secs_f64() / REPS as f64;

        // Two-mode Exact: the bit-exact chain pays S sequential delayed
        // round-trips (≈ S·δ); the pipelined ExpSumPart fan-out pays
        // the slowest worker (≈ δ) — max-over-workers latency for the
        // last-ulp cost documented in net::remote.
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.exp_sum_batch(&queries).expect("chained exp-sum");
        }
        let chain_s = t0.elapsed().as_secs_f64() / REPS as f64;
        let t0 = Instant::now();
        for _ in 0..REPS {
            cluster.exp_sum_parts(&queries).expect("pipelined exp-sum");
        }
        let pipe_s = t0.elapsed().as_secs_f64() / REPS as f64;
        drop(cluster);

        let speedup = seq_s / par_s;
        let z_speedup = chain_s / pipe_s;
        println!(
            "workers={s}: publish sequential {:.2} ms vs parallel {:.2} ms => {speedup:.2}x; \
             exact chained {:.2} ms vs pipelined {:.2} ms => {z_speedup:.2}x",
            seq_s * 1e3,
            par_s * 1e3,
            chain_s * 1e3,
            pipe_s * 1e3
        );
        table.row(vec![
            s.to_string(),
            format!("{:.2}", seq_s * 1e3),
            format!("{:.2}", par_s * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.2}", chain_s * 1e3),
            format!("{:.2}", pipe_s * 1e3),
            format!("{z_speedup:.2}x"),
        ]);
        rows_json.push(Json::obj(vec![
            ("workers", Json::num(s as f64)),
            ("seq_publish_s", Json::num(seq_s)),
            ("par_publish_s", Json::num(par_s)),
            ("speedup", Json::num(speedup)),
            ("chained_expsum_s", Json::num(chain_s)),
            ("pipelined_expsum_s", Json::num(pipe_s)),
            ("expsum_speedup", Json::num(z_speedup)),
        ]));

        for server in servers {
            server.shutdown();
        }
    }

    table.print();
    let json = Json::obj(vec![
        ("delay_ms", Json::num(DELAY.as_millis() as f64)),
        ("reps", Json::num(REPS as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    std::fs::write("BENCH_fanout.json", json.to_string()).ok();
    println!("(json: BENCH_fanout.json)");
    bench_common::write_json(env, "fanout", &json);
}

/// Wire-v3 connection-scale benchmarks: the reactor pool under many
/// concurrent connections, and multiplexed pipelined scatters at
/// increasing in-flight depth. Writes `BENCH_reactor.json`.
fn reactor_section(env: &bench_common::BenchEnv, store: &zest::data::embeddings::EmbeddingStore) {
    // -- Concurrent-clients sweep: C connections on a 2-thread reactor
    // pool, R manifest RPCs each; aggregate throughput per point.
    const RPCS_PER_CLIENT: usize = 20;
    println!("\n== reactor: concurrent-clients sweep ({RPCS_PER_CLIENT} RPCs/client) ==");
    let server = Server::serve(
        &Addr::Tcp("127.0.0.1:0".to_string()),
        Arc::new(ShardWorker::new(store.clone())),
        ServerConfig {
            max_connections: 300,
            reactor_threads: 2,
            handler_threads: 8,
            ..Default::default()
        },
        Arc::new(ServiceMetrics::new()),
    )
    .expect("bind sweep server");
    let addr = server.local_addr().clone();
    let mut conn_table = Table::new(&["clients", "wall (ms)", "RPC/s"]);
    let mut conn_rows: Vec<Json> = Vec::new();
    for clients in [1usize, 8, 64, 256] {
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..clients {
                let addr = addr.clone();
                scope.spawn(move || {
                    let (shard, _) = RemoteShard::connect(addr, ClientConfig::default())
                        .expect("connect sweep client");
                    for _ in 0..RPCS_PER_CLIENT {
                        shard.manifest().expect("manifest");
                    }
                });
            }
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let rps = (clients * RPCS_PER_CLIENT) as f64 / wall_s;
        println!("clients={clients}: {:.2} ms wall, {rps:.0} RPC/s", wall_s * 1e3);
        conn_table.row(vec![
            clients.to_string(),
            format!("{:.2}", wall_s * 1e3),
            format!("{rps:.0}"),
        ]);
        conn_rows.push(Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            ("wall_s", Json::num(wall_s)),
            ("rps", Json::num(rps)),
        ]));
    }
    conn_table.print();
    server.shutdown();

    // -- Pipelined-RPC depth sweep: S delayed workers, D concurrent
    // scatters sharing one multiplexed connection per worker. Each
    // scatter pays ≈ max-over-workers (δ); overlapped depth divides the
    // effective per-scatter latency instead of multiplying the wall
    // clock — the "max, not sum" pipeline claim in net::remote.
    const SWEEP_WORKERS: usize = 4;
    println!(
        "\n== reactor: pipelined depth sweep ({SWEEP_WORKERS} workers, δ={}ms/op, {REPS} reps) ==",
        DELAY.as_millis()
    );
    let queries: Vec<Vec<f32>> = (0..4).map(|i| store.row(i * 16).to_vec()).collect();
    let mut servers = Vec::new();
    let mut addrs: Vec<Addr> = Vec::new();
    for block in aligned_split(store, SWEEP_WORKERS) {
        let server = Server::serve(
            &Addr::Tcp("127.0.0.1:0".to_string()),
            Arc::new(SlowPublish {
                inner: ShardWorker::new(block),
            }),
            ServerConfig {
                handler_threads: 16,
                ..Default::default()
            },
            Arc::new(ServiceMetrics::new()),
        )
        .expect("bind depth-sweep worker");
        addrs.push(server.local_addr().clone());
        servers.push(server);
    }
    let cluster =
        RemoteCluster::connect(&addrs, ClientConfig::default()).expect("connect depth cluster");
    let delay_s = DELAY.as_secs_f64();
    let mut depth_table = Table::new(&[
        "depth",
        "wall (ms)",
        "per-scatter (ms)",
        "max model (ms)",
        "sum model (ms)",
    ]);
    let mut depth_rows: Vec<Json> = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..depth {
                let cluster = &cluster;
                let queries = &queries;
                scope.spawn(move || {
                    for _ in 0..REPS {
                        cluster.exp_sum_parts(queries).expect("pipelined scatter");
                    }
                });
            }
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let per_scatter_s = wall_s / (depth * REPS) as f64;
        // A scatter's floor is one delayed op on the slowest worker
        // (max model, ≈ δ); a serialized pipeline would cost every
        // overlapped scatter its own δ in turn (sum model, ≈ depth·δ
        // per wall-clock slot).
        let max_model_s = delay_s;
        let sum_model_s = delay_s * depth as f64;
        println!(
            "depth={depth}: wall {:.2} ms, per-scatter {:.3} ms (max model {:.1} ms, \
             serialized model {:.1} ms)",
            wall_s * 1e3,
            per_scatter_s * 1e3,
            max_model_s * 1e3,
            sum_model_s * 1e3
        );
        depth_table.row(vec![
            depth.to_string(),
            format!("{:.2}", wall_s * 1e3),
            format!("{:.3}", per_scatter_s * 1e3),
            format!("{:.1}", max_model_s * 1e3),
            format!("{:.1}", sum_model_s * 1e3),
        ]);
        depth_rows.push(Json::obj(vec![
            ("depth", Json::num(depth as f64)),
            ("wall_s", Json::num(wall_s)),
            ("per_scatter_s", Json::num(per_scatter_s)),
            ("max_model_s", Json::num(max_model_s)),
            ("sum_model_s", Json::num(sum_model_s)),
        ]));
    }
    depth_table.print();
    drop(cluster);
    for server in servers {
        server.shutdown();
    }

    let json = Json::obj(vec![
        (
            "connection_sweep",
            Json::obj(vec![
                ("rpcs_per_client", Json::num(RPCS_PER_CLIENT as f64)),
                ("rows", Json::Arr(conn_rows)),
            ]),
        ),
        (
            "depth_sweep",
            Json::obj(vec![
                ("workers", Json::num(SWEEP_WORKERS as f64)),
                ("delay_ms", Json::num(DELAY.as_millis() as f64)),
                ("reps", Json::num(REPS as f64)),
                ("rows", Json::Arr(depth_rows)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_reactor.json", json.to_string()).ok();
    println!("(json: BENCH_reactor.json)");
    bench_common::write_json(env, "reactor", &json);
}

/// Front-door benchmarks: cold-vs-warm latency under Zipf-skewed repeat
/// mixes, and the coalesced-herd wall time. Writes
/// `BENCH_frontdoor.json`.
fn frontdoor_section(env: &bench_common::BenchEnv) {
    use zest::coordinator::{EstimateSpec, PartitionService, Router, ServiceConfig};
    use zest::store::{ShardedStore, SnapshotHandle};
    use zest::util::rng::{Rng, Zipf};

    /// Distinct queries in the pool each mix draws from.
    const POOL: usize = 64;
    /// Sequential requests per mix.
    const REQUESTS: usize = 512;
    /// Identical concurrent requests in the herd measurement.
    const HERD: usize = 64;

    let store = bench_common::store(env);
    let stride = store.len() / POOL;
    let pool: Vec<Vec<f32>> = (0..POOL).map(|i| store.row(i * stride).to_vec()).collect();

    // The in-process service over a local snapshot: the front door is a
    // coordinator stage, so no sockets are needed to measure it.
    let start_service = || {
        PartitionService::start_sharded(
            Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 2))),
            Router::new(Default::default()),
            ServiceConfig {
                workers: 2,
                ..Default::default()
            },
            None,
        )
    };
    let p50_s = |lat: &mut Vec<Duration>| -> f64 {
        if lat.is_empty() {
            return 0.0;
        }
        lat.sort();
        lat[lat.len() / 2].as_secs_f64()
    };

    println!(
        "\n== frontdoor: repeat-rate sweep ({REQUESTS} Exact requests, pool of {POOL}) =="
    );
    let mut table = Table::new(&[
        "mix",
        "hit rate",
        "cold p50 (µs)",
        "warm p50 (µs)",
        "warm speedup",
    ]);
    let mut mix_rows: Vec<Json> = Vec::new();
    for mix in ["uniform", "zipf-1.0", "all-identical"] {
        let svc = start_service();
        let mut rng = Rng::seeded(7);
        let zipf = Zipf::new(POOL, 1.0);
        let mut cold: Vec<Duration> = Vec::new();
        let mut warm: Vec<Duration> = Vec::new();
        for _ in 0..REQUESTS {
            let qi = match mix {
                "uniform" => rng.below(POOL),
                "zipf-1.0" => zipf.sample(&mut rng),
                _ => 0,
            };
            let t0 = Instant::now();
            let r = svc
                .estimate(EstimateSpec::new(pool[qi].clone()))
                .expect("estimate");
            let lat = t0.elapsed();
            if r.served_from_cache {
                warm.push(lat);
            } else {
                cold.push(lat);
            }
        }
        let hit_rate = warm.len() as f64 / REQUESTS as f64;
        let (cold_p50, warm_p50) = (p50_s(&mut cold), p50_s(&mut warm));
        let speedup = cold_p50 / warm_p50.max(1e-9);
        println!(
            "mix={mix}: hit rate {:.3}, cold p50 {:.1} µs vs warm p50 {:.1} µs => {speedup:.0}x",
            hit_rate,
            cold_p50 * 1e6,
            warm_p50 * 1e6
        );
        table.row(vec![
            mix.to_string(),
            format!("{hit_rate:.3}"),
            format!("{:.1}", cold_p50 * 1e6),
            format!("{:.1}", warm_p50 * 1e6),
            format!("{speedup:.0}x"),
        ]);
        mix_rows.push(Json::obj(vec![
            ("mix", Json::str(mix)),
            ("hit_rate", Json::num(hit_rate)),
            ("cold_p50_s", Json::num(cold_p50)),
            ("warm_p50_s", Json::num(warm_p50)),
            ("warm_speedup", Json::num(speedup)),
        ]));
        svc.shutdown();
    }
    table.print();

    // Coalesced herd: HERD identical concurrent requests on a cold
    // cache, released together — single-flight rides one batcher slot
    // and one execution, so the wall time is ~one cold request.
    let svc = start_service();
    let q = pool[POOL - 1].clone();
    let barrier = std::sync::Barrier::new(HERD);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..HERD {
            let (svc, q, barrier) = (&svc, &q, &barrier);
            scope.spawn(move || {
                barrier.wait();
                svc.estimate(EstimateSpec::new(q.clone()))
                    .expect("herd estimate");
            });
        }
    });
    let herd_wall_s = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    println!(
        "herd: {HERD} identical concurrent requests in {:.2} ms \
         ({} coalesced, {} executed)",
        herd_wall_s * 1e3,
        m.coalesced,
        m.cache_misses
    );
    svc.shutdown();

    let json = Json::obj(vec![
        ("pool", Json::num(POOL as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("mixes", Json::Arr(mix_rows)),
        (
            "herd",
            Json::obj(vec![
                ("size", Json::num(HERD as f64)),
                ("wall_s", Json::num(herd_wall_s)),
                ("coalesced", Json::num(m.coalesced as f64)),
                ("executed", Json::num(m.cache_misses as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_frontdoor.json", json.to_string()).ok();
    println!("(json: BENCH_frontdoor.json)");
    bench_common::write_json(env, "frontdoor", &json);
}

/// Observability overhead guard (§obs): the same executed-request
/// workload with tracing off, 1%-sampled, and fully sampled. Tracing
/// off must be free (the `Option<Trace>` fast path allocates nothing);
/// even 100% sampling only adds a handful of `Instant::now()` calls and
/// one ring insert per request. Records the measured ratios to
/// `BENCH_obs.json` — no hard assert, machines vary, but a ratio far
/// from 1.0 at rate 0 is a regression worth chasing.
fn obs_section(env: &bench_common::BenchEnv) {
    use zest::coordinator::{EstimateSpec, PartitionService, Router, ServiceConfig};
    use zest::store::{ShardedStore, SnapshotHandle};

    /// Distinct queries cycled through each run.
    const POOL: usize = 16;
    /// Sequential executed requests per sampling rate.
    const REQUESTS: usize = 512;

    let store = bench_common::store(env);
    let stride = store.len() / POOL;
    let pool: Vec<Vec<f32>> = (0..POOL).map(|i| store.row(i * stride).to_vec()).collect();

    println!("\n== obs: tracing overhead ({REQUESTS} executed requests per rate) ==");
    let mut table = Table::new(&["sample rate", "wall (ms)", "per-req (µs)", "vs off"]);
    let mut rate_rows: Vec<Json> = Vec::new();
    let mut base_per_req_s = 0.0f64;
    for rate in [0.0f64, 0.01, 1.0] {
        // Cache off so every request runs the full pipeline — a cache
        // hit would skip the very stages the trace instruments.
        let svc = PartitionService::start_sharded(
            Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 2))),
            Router::new(Default::default()),
            ServiceConfig {
                workers: 2,
                cache_entries: 0,
                trace_sample_rate: rate,
                ..Default::default()
            },
            None,
        );
        // Warm the pipeline before timing.
        for q in pool.iter().take(4) {
            svc.estimate(EstimateSpec::new(q.clone())).expect("warmup");
        }
        let t0 = Instant::now();
        for i in 0..REQUESTS {
            svc.estimate(EstimateSpec::new(pool[i % POOL].clone()))
                .expect("estimate");
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let per_req_s = wall_s / REQUESTS as f64;
        if rate == 0.0 {
            base_per_req_s = per_req_s;
        }
        let ratio = per_req_s / base_per_req_s.max(1e-12);
        println!(
            "rate={rate}: wall {:.2} ms, per-request {:.1} µs ({ratio:.2}x vs off)",
            wall_s * 1e3,
            per_req_s * 1e6
        );
        table.row(vec![
            format!("{rate}"),
            format!("{:.2}", wall_s * 1e3),
            format!("{:.1}", per_req_s * 1e6),
            format!("{ratio:.2}x"),
        ]);
        rate_rows.push(Json::obj(vec![
            ("sample_rate", Json::num(rate)),
            ("wall_s", Json::num(wall_s)),
            ("per_request_s", Json::num(per_req_s)),
            ("ratio_vs_off", Json::num(ratio)),
        ]));
        svc.shutdown();
    }
    table.print();

    let json = Json::obj(vec![
        ("pool", Json::num(POOL as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("rates", Json::Arr(rate_rows)),
    ]);
    std::fs::write("BENCH_obs.json", json.to_string()).ok();
    println!("(json: BENCH_obs.json)");
    bench_common::write_json(env, "obs", &json);
}

/// Replica-failover cost (§failover): scatter-read p50/p99 with every
/// replica healthy vs with one replica of **each** shard dead (the
/// failed picks absorbed by transparent failover), plus the wall time
/// of the `refresh()` that heals the dead replicas once they return.
/// Writes `BENCH_failover.json`.
fn failover_section(env: &bench_common::BenchEnv, store: &zest::data::embeddings::EmbeddingStore) {
    use zest::testing::fault::{FaultMode, FaultProxy};

    /// Shards × replicas in the measured cluster.
    const SHARDS: usize = 2;
    /// Scatter reads per phase (healthy / one-dead).
    const READS: usize = 200;

    let pctl = |lat: &mut Vec<Duration>, p: usize| -> f64 {
        lat.sort();
        lat[(lat.len() * p / 100).min(lat.len() - 1)].as_secs_f64()
    };

    println!("\n== failover: scatter reads, {SHARDS} shards × 2 replicas ({READS} reads/phase) ==");
    // Replica 0 of each shard sits behind a fault proxy (so "kill" is
    // sever + refuse, exactly the chaos-test action); replica 1 is
    // direct.
    let mut servers = Vec::new();
    let mut proxies = Vec::new();
    let mut groups: Vec<Vec<Addr>> = Vec::new();
    for block in aligned_split(store, SHARDS) {
        let mut group = Vec::new();
        for r in 0..2 {
            let server = Server::serve(
                &Addr::Tcp("127.0.0.1:0".to_string()),
                Arc::new(ShardWorker::new(block.clone())),
                ServerConfig::default(),
                Arc::new(ServiceMetrics::new()),
            )
            .expect("bind failover worker");
            let addr = server.local_addr().clone();
            servers.push(server);
            if r == 0 {
                let proxy = FaultProxy::start(&Addr::Tcp("127.0.0.1:0".to_string()), addr)
                    .expect("start fault proxy");
                group.push(proxy.addr().clone());
                proxies.push(proxy);
            } else {
                group.push(addr);
            }
        }
        groups.push(group);
    }
    let cluster = RemoteCluster::connect_groups(&groups, ClientConfig::default())
        .expect("connect failover cluster");
    let q = store.row(0).to_vec();

    // Phase 1: every replica healthy.
    let mut healthy: Vec<Duration> = Vec::with_capacity(READS);
    for _ in 0..READS {
        let t0 = Instant::now();
        cluster.exp_sum(&q).expect("healthy read");
        healthy.push(t0.elapsed());
    }

    // Phase 2: replica 0 of every shard dead. The first read(s) pay the
    // failover discovery (p99); the rest route straight to the
    // survivors (p50).
    for proxy in &proxies {
        proxy.set_mode(FaultMode::Refuse);
        proxy.cut_all();
    }
    let mut one_dead: Vec<Duration> = Vec::with_capacity(READS);
    for _ in 0..READS {
        let t0 = Instant::now();
        cluster.exp_sum(&q).expect("read with one replica dead");
        one_dead.push(t0.elapsed());
    }
    let failovers = cluster.failovers();

    // Lag the dead replicas by one publish, bring them back, and time
    // the publish-log heal.
    cluster.remove_categories(&[]).expect("publish while dead");
    for proxy in &proxies {
        proxy.restore();
    }
    let t0 = Instant::now();
    cluster.refresh().expect("healing refresh");
    let heal_s = t0.elapsed().as_secs_f64();
    assert!(
        cluster.replica_status().iter().all(|g| g.iter().all(|&h| h)),
        "refresh did not restore full health"
    );

    let (h50, h99) = (pctl(&mut healthy, 50), pctl(&mut healthy, 99));
    let (d50, d99) = (pctl(&mut one_dead, 50), pctl(&mut one_dead, 99));
    let mut table = Table::new(&["phase", "p50 (µs)", "p99 (µs)"]);
    table.row(vec![
        "healthy".to_string(),
        format!("{:.1}", h50 * 1e6),
        format!("{:.1}", h99 * 1e6),
    ]);
    table.row(vec![
        "one replica dead".to_string(),
        format!("{:.1}", d50 * 1e6),
        format!("{:.1}", d99 * 1e6),
    ]);
    table.print();
    println!(
        "failovers={failovers}; time-to-heal (refresh with 2 lagged replicas): {:.2} ms",
        heal_s * 1e3
    );

    let json = Json::obj(vec![
        ("shards", Json::num(SHARDS as f64)),
        ("replicas", Json::num(2.0)),
        ("reads_per_phase", Json::num(READS as f64)),
        (
            "healthy",
            Json::obj(vec![
                ("p50_s", Json::num(h50)),
                ("p99_s", Json::num(h99)),
            ]),
        ),
        (
            "one_dead",
            Json::obj(vec![
                ("p50_s", Json::num(d50)),
                ("p99_s", Json::num(d99)),
            ]),
        ),
        ("failovers", Json::num(failovers as f64)),
        ("heal_s", Json::num(heal_s)),
    ]);
    std::fs::write("BENCH_failover.json", json.to_string()).ok();
    println!("(json: BENCH_failover.json)");
    bench_common::write_json(env, "failover", &json);

    drop(cluster);
    drop(proxies);
    for server in servers {
        server.shutdown();
    }
}
