//! Figure 1 bench: regenerate the CDF-over-sorted-contributions curves.
//! Paper shape: <1k neighbors cover 80% of Z for rare words; ~80% of the
//! whole vocabulary is needed for the most frequent words.

mod bench_common;

fn main() {
    let env = bench_common::env();
    let store = bench_common::store(&env);
    println!(
        "== Figure 1 (scale={}, N={}, d={}) ==",
        env.scale, env.cfg.n, env.cfg.d
    );
    let t0 = std::time::Instant::now();
    let curves = zest::experiments::figure1::run(&store, &env.synth, env.cfg.threads);
    println!(
        "{:>8} {:>14} {:>9} {:>9} {:>9} {:>8}",
        "rank", "corpus freq", "n@50%", "n@80%", "n@90%", "n80/N"
    );
    for c in &curves {
        println!(
            "{:>8} {:>14} {:>9} {:>9} {:>9} {:>8.3}",
            c.rank,
            c.corpus_freq,
            c.n50,
            c.n80,
            c.n90,
            c.n80 as f64 / store.len() as f64
        );
    }
    println!("(wall: {:?})", t0.elapsed());
    bench_common::write_json(&env, "figure1", &zest::experiments::figure1::to_json(&curves));
}
