//! Loadgen self-bench: how fast can the generator *generate*?
//!
//! The open-loop invariant only holds while the scheduler outpaces the
//! offered rate — if sampling the mix or materializing a spec were
//! slower than the inter-arrival gap, the schedule itself would become
//! the bottleneck and silently depress offered load. This bench pins
//! the dispatch-path cost (schedule step + Zipf draw + spec build) so
//! a regression is visible as a ceiling on sustainable offered rates.

mod bench_common;

use zest::loadgen::{default_classes, Arrival, Schedule, WorkloadMix};
use zest::util::json::Json;
use zest::util::rng::Rng;

fn main() {
    let env = bench_common::env();
    let users = if env.scale == "paper" { 100_000 } else { 10_000 };
    let dim = 64;
    println!("== loadgen dispatch path (users={users}, d={dim}) ==");

    let draws = 2_000_000u64;
    let mut rows = Vec::new();
    for arrival in [Arrival::Fixed, Arrival::Poisson] {
        let t0 = std::time::Instant::now();
        let mut acc = std::time::Duration::ZERO;
        for at in Schedule::new(1e6, arrival, 7).take(draws as usize) {
            acc += at;
        }
        let wall = t0.elapsed();
        let hz = draws as f64 / wall.as_secs_f64();
        println!("schedule/{arrival}: {hz:>12.0} steps/s (checksum {acc:?})");
        rows.push((format!("schedule_{arrival}_hz"), hz));
    }

    let mix = WorkloadMix::new(users, 1.1, dim, default_classes(), 7);
    let mut rng = Rng::seeded(9);
    let t0 = std::time::Instant::now();
    let mut sink = 0usize;
    for _ in 0..draws {
        let req = mix.sample(&mut rng);
        sink ^= req.user ^ req.class;
    }
    let wall = t0.elapsed();
    let sample_hz = draws as f64 / wall.as_secs_f64();
    println!("mix sample:       {sample_hz:>12.0} draws/s (sink {sink})");
    rows.push(("mix_sample_hz".to_string(), sample_hz));

    let specs = 200_000u64;
    let t0 = std::time::Instant::now();
    let mut dims = 0usize;
    for _ in 0..specs {
        let req = mix.sample(&mut rng);
        dims += mix.spec(req).query.len();
    }
    let wall = t0.elapsed();
    let spec_hz = specs as f64 / wall.as_secs_f64();
    println!("spec build:       {spec_hz:>12.0} specs/s (dims {dims})");
    rows.push(("spec_build_hz".to_string(), spec_hz));

    let json = Json::obj(
        rows.iter()
            .map(|(k, v)| (k.as_str(), Json::num(*v)))
            .collect(),
    );
    bench_common::write_json(&env, "loadgen_dispatch", &json);
    // CI-visible copy at the package root, like the fanout sections.
    std::fs::write("BENCH_loadgen_dispatch.json", json.to_string()).ok();
    println!("(json: BENCH_loadgen_dispatch.json)");
}
