//! Table 4 bench: the end-to-end LBL experiment — train with NCE (Z
//! clamped to 1) through the PJRT artifact, then compare MIMPS partition
//! estimates against the Z=1 heuristic on held-out contexts.
//! Paper shape: at k=100 MIMPS beats the heuristic (%Better > 50) with
//! ~10–18× speedup over brute force.

mod bench_common;

use zest::experiments::table4::{render, run, to_json, Table4Config};

fn main() {
    let env = bench_common::env();
    let dir = std::path::PathBuf::from(&env.cfg.artifacts_dir);
    let meta = match zest::runtime::ArtifactsMeta::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("table4 bench needs artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let steps = std::env::var("ZEST_LBL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(match env.scale.as_str() {
            "paper" => 2000usize,
            _ => 600,
        });
    let contexts = match env.scale.as_str() {
        "paper" => 10_000,
        _ => 2_000,
    };
    let cfg = Table4Config {
        lbl: zest::lm::LblConfig {
            vocab: meta.config_usize("vocab").unwrap(),
            d: meta.config_usize("lbl_d").unwrap(),
            ctx: meta.config_usize("ctx").unwrap(),
            seed: env.cfg.seed,
        },
        nce: zest::lm::NceConfig {
            batch: meta.config_usize("lbl_batch").unwrap(),
            noise_k: meta.config_usize("noise_k").unwrap(),
            lr: 0.3,
        },
        train_steps: steps,
        contexts,
        corpus: zest::data::corpus::CorpusConfig {
            vocab: meta.config_usize("vocab").unwrap(),
            seed: env.cfg.seed,
            ..Default::default()
        },
        threads: env.cfg.threads,
        ..Default::default()
    };
    println!(
        "== Table 4 (scale={}, vocab={}, d={}, ctx={}, steps={}, contexts={}) ==",
        env.scale, cfg.lbl.vocab, cfg.lbl.d, cfg.lbl.ctx, steps, contexts
    );
    let (rt, join) =
        zest::runtime::spawn_runtime_thread(dir.clone(), Some(vec!["lbl_nce_step".into()]))
            .expect("runtime");
    let t0 = std::time::Instant::now();
    let t = run(&cfg, &rt, &dir).expect("table4");
    print!("{}", render(&t));
    println!("(wall: {:?})", t0.elapsed());
    rt.shutdown();
    join.join().ok();
    bench_common::write_json(&env, "table4", &to_json(&t));
}
