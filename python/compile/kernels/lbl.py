"""L1 Pallas kernels for the log-bilinear language model's serving path:
the context combination (diagonal context matrices, Mnih & Teh 2012) and
candidate scoring. Training uses the jnp oracles in ref.py because the
training step differentiates through these ops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _lbl_context_kernel(r_ref, c_ref, o_ref):
    """One batch tile: q_hat = sum_j c_j * r_ctx[:, j, :]."""
    o_ref[...] = jnp.sum(r_ref[...] * c_ref[...][None, :, :], axis=1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def lbl_context(r_ctx, c, *, block_b: int = DEFAULT_BLOCK_B):
    """Context combination. r_ctx: (b, ctx, d), c: (ctx, d) -> (b, d)."""
    b, ctx, d = r_ctx.shape
    block_b = min(block_b, b)
    pad = (block_b - b % block_b) % block_b
    if pad:
        r_ctx = jnp.pad(r_ctx, ((0, pad), (0, 0), (0, 0)))
    grid = (r_ctx.shape[0] // block_b,)
    out = pl.pallas_call(
        _lbl_context_kernel,
        out_shape=jax.ShapeDtypeStruct((r_ctx.shape[0], d), r_ctx.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, ctx, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ctx, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        interpret=True,
    )(r_ctx, c)
    return out[:b]


def _lbl_scores_kernel(q_ref, e_ref, b_ref, o_ref):
    """One batch tile: s[t, k] = q_hat_t . cand_emb[t, k] + cand_bias[t, k]."""
    q = q_ref[...]  # (blk, d)
    e = e_ref[...]  # (blk, k, d)
    o_ref[...] = jnp.einsum("bd,bkd->bk", q, e) + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def lbl_scores(q_hat, cand_emb, cand_bias, *, block_b: int = DEFAULT_BLOCK_B):
    """Candidate scores. q_hat: (b, d), cand_emb: (b, k, d),
    cand_bias: (b, k) -> (b, k)."""
    b, d = q_hat.shape
    k = cand_emb.shape[1]
    block_b = min(block_b, b)
    pad = (block_b - b % block_b) % block_b
    if pad:
        q_hat = jnp.pad(q_hat, ((0, pad), (0, 0)))
        cand_emb = jnp.pad(cand_emb, ((0, pad), (0, 0), (0, 0)))
        cand_bias = jnp.pad(cand_bias, ((0, pad), (0, 0)))
    grid = (q_hat.shape[0] // block_b,)
    out = pl.pallas_call(
        _lbl_scores_kernel,
        out_shape=jax.ShapeDtypeStruct((q_hat.shape[0], k), q_hat.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        interpret=True,
    )(q_hat, cand_emb, cand_bias)
    return out[:b]
