"""L1 Pallas kernel for the FMBE hot spot: Kar-Karnick degree-m feature
products, batched so the projections run as one (b, d) x (d, j*m) matmul
per degree instead of j*m independent GEMVs (the MXU adaptation of the
paper's random-feature evaluation).

x: (b, d) inputs, w: (j, m, d) Rademacher projections ->
out: (b, j) with out[t, f] = prod_r (x_t . w[f, r, :]).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _degree_prod_kernel(x_ref, w_ref, o_ref, *, m: int):
    """One batch tile: T = X_blk @ W^T -> (blk, j*m); product-reduce the
    degree axis in VMEM."""
    x = x_ref[...]  # (blk, d)
    w = w_ref[...]  # (j, m, d)
    j = w.shape[0]
    wf = w.reshape(j * m, w.shape[2])  # (j*m, d)
    t = x @ wf.T  # (blk, j*m) — the MXU matmul
    t = t.reshape(x.shape[0], j, m)
    o_ref[...] = jnp.prod(t, axis=2)


@functools.partial(jax.jit, static_argnames=("block_b",))
def degree_prod(x, w, *, block_b: int = DEFAULT_BLOCK_B):
    """Degree-m feature products. x: (b, d), w: (j, m, d) -> (b, j)."""
    b, d = x.shape
    j, m = w.shape[0], w.shape[1]
    if m == 0:
        return jnp.ones((b, j), dtype=x.dtype)
    block_b = min(block_b, b)
    pad = (block_b - b % block_b) % block_b
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // block_b,)
    out = pl.pallas_call(
        functools.partial(_degree_prod_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], j), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((j, m, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, j), lambda i: (i, 0)),
        interpret=True,
    )(x, w)
    return out[:b]
