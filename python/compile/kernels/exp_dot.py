"""L1 Pallas kernels for the scoring hot spot: exp(V q) and its partial
partition sums, tiled over the category axis.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's 2015
CPU hot loop (FLANN scalar dots) is re-thought for a TPU-style memory
hierarchy. The category matrix is streamed HBM -> VMEM in (BLOCK_N, d)
tiles declared via BlockSpec; the dot products hit the MXU-friendly
matmul path; exp and the block-level reduction happen in VMEM before a
single f32 partial sum (or score tile) is written back. The grid
iterates over N/BLOCK_N, which is exactly the double-buffered
HBM<->VMEM schedule a GPU version would express with threadblocks.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and AOT artifacts must stay loadable by the rust
runtime. Real-TPU perf is estimated from the BlockSpec footprint in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile height: 1024 rows x 300 cols x 4 B = 1.2 MiB « 16 MiB VMEM,
# leaving room for double buffering plus the query and output tiles.
DEFAULT_BLOCK_N = 1024


def _exp_dot_kernel(v_ref, q_ref, o_ref):
    """One tile: o = exp(V_blk @ q)."""
    o_ref[...] = jnp.exp(v_ref[...] @ q_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n",))
def exp_dot(v, q, *, block_n: int = DEFAULT_BLOCK_N):
    """exp(v_i . q) over a chunk. v: (n, d), q: (d,) -> (n,)."""
    n, d = v.shape
    block_n = min(block_n, n)
    if n % block_n != 0:  # pad to a whole number of tiles
        pad = block_n - n % block_n
        v = jnp.pad(v, ((0, pad), (0, 0)))
    grid = (v.shape[0] // block_n,)
    out = pl.pallas_call(
        _exp_dot_kernel,
        out_shape=jax.ShapeDtypeStruct((v.shape[0],), v.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        interpret=True,
    )(v, q)
    return out[:n]


def _partition_kernel(v_ref, q_ref, o_ref):
    """One tile: o = sum(exp(V_blk @ q)) — per-block partial sum."""
    o_ref[...] = jnp.sum(jnp.exp(v_ref[...] @ q_ref[...]), keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n",))
def partition_chunk(v, q, *, block_n: int = DEFAULT_BLOCK_N):
    """sum_i exp(v_i . q) -> () f32.

    Padding note: padded rows would contribute exp(0) = 1 each, so the
    kernel output is corrected by the pad count afterwards.
    """
    n, d = v.shape
    block_n = min(block_n, n)
    pad = (block_n - n % block_n) % block_n
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    grid = (v.shape[0] // block_n,)
    partials = pl.pallas_call(
        _partition_kernel,
        out_shape=jax.ShapeDtypeStruct((grid[0],), v.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,
    )(v, q)
    return jnp.sum(partials) - jnp.float32(pad)


def _score_batch_kernel(v_ref, qs_ref, o_ref):
    """One tile: o[b] = sum_i exp(q_b . v_i) over the tile's rows.

    The (block_n, d) x (d, b) matmul is the MXU work; exp + reduce fuse
    in VMEM. Accumulation across tiles happens via the grid-carried
    output block (same index_map for every i -> accumulate pattern).
    """
    tile = jnp.exp(qs_ref[...] @ v_ref[...].T)  # (b, block_n)
    acc = jnp.sum(tile, axis=1)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("block_n",))
def score_batch(v, qs, *, block_n: int = DEFAULT_BLOCK_N):
    """Partial partition sums for a batch: v (n, d), qs (b, d) -> (b,)."""
    n, d = v.shape
    b = qs.shape[0]
    block_n = min(block_n, n)
    pad = (block_n - n % block_n) % block_n
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    grid = (v.shape[0] // block_n,)
    out = pl.pallas_call(
        _score_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), v.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        interpret=True,
    )(v, qs)
    return out - jnp.float32(pad)
