"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness contracts: `pytest python/tests` sweeps shapes
and dtypes (via hypothesis) asserting each kernel matches its oracle to
float tolerance. The oracles are also used directly inside the L2
training-step graph, where autodiff through `pallas_call` is not needed.
"""

import jax.numpy as jnp


def exp_dot(v, q):
    """exp(v_i . q) for a chunk of category vectors.

    v: (n, d) f32, q: (d,) f32 -> (n,) f32
    """
    return jnp.exp(v @ q)


def partition_chunk(v, q):
    """Partial partition sum over a chunk: sum_i exp(v_i . q) -> () f32."""
    return jnp.sum(jnp.exp(v @ q), dtype=jnp.float32)


def score_batch(v, qs):
    """Partial partition sums for a batch of queries.

    v: (n, d), qs: (b, d) -> (b,) with out[j] = sum_i exp(v_i . q_j)
    """
    return jnp.sum(jnp.exp(qs @ v.T), axis=1, dtype=jnp.float32)


def degree_prod(x, w):
    """Kar-Karnick degree-m feature products (FMBE hot spot).

    x: (b, d) queries, w: (j, m, d) Rademacher projections ->
    (b, j) products prod_r (x . w[j, r, :]).  m == 0 -> ones.
    """
    b = x.shape[0]
    j, m = w.shape[0], w.shape[1]
    if m == 0:
        return jnp.ones((b, j), dtype=x.dtype)
    t = jnp.einsum("bd,jmd->bjm", x, w)
    return jnp.prod(t, axis=2)


def lbl_context(r_ctx, c):
    """Log-bilinear context combination with diagonal context matrices
    (Mnih & Teh 2012): q_hat = sum_j c_j * r_{w_j}.

    r_ctx: (b, ctx, d) gathered context embeddings,
    c:     (ctx, d) per-position diagonal weights -> (b, d)
    """
    return jnp.sum(r_ctx * c[None, :, :], axis=1)


def lbl_scores(q_hat, cand_emb, cand_bias):
    """LBL scores for candidate words: s = q_hat . r_w + b_w.

    q_hat: (b, d), cand_emb: (b, k, d), cand_bias: (b, k) -> (b, k)
    """
    return jnp.einsum("bd,bkd->bk", q_hat, cand_emb) + cand_bias
