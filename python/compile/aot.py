"""AOT exporter: lower every L2 graph to HLO *text* under artifacts/.

HLO text — not ``lowered.compile()`` output or serialized HloModuleProto
— is the interchange format: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (behind the rust `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Writes ``<name>.hlo.txt`` per graph plus ``meta.json`` (shapes, dtypes,
argument order) which the rust runtime reads to marshal literals.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export(out_dir: str, cfg: dict) -> dict:
    """Lower all graphs; return the meta dict."""
    os.makedirs(out_dir, exist_ok=True)
    chunk, d, batch = cfg["chunk"], cfg["d"], cfg["batch"]
    vocab, lbl_d, ctx, kn, lbl_b = (
        cfg["vocab"],
        cfg["lbl_d"],
        cfg["ctx"],
        cfg["noise_k"],
        cfg["lbl_batch"],
    )
    fm_j, fm_m = cfg["fm_j"], cfg["fm_m"]
    i32 = jnp.int32

    graphs = {
        "score_chunk": (
            model.score_chunk,
            [spec((chunk, d)), spec((d,))],
        ),
        "partition_chunk": (
            model.partition_chunk,
            [spec((chunk, d)), spec((d,))],
        ),
        "score_batch": (
            model.score_batch,
            [spec((chunk, d)), spec((batch, d))],
        ),
        "fmbe_query": (
            model.fmbe_query,
            [spec((batch, d)), spec((fm_j, fm_m, d))],
        ),
        "lbl_qhat": (
            model.lbl_qhat,
            [spec((vocab, lbl_d)), spec((ctx, lbl_d)), spec((lbl_b, ctx), i32)],
        ),
        "lbl_nce_step": (
            model.lbl_nce_step,
            [
                spec((vocab, lbl_d)),          # r
                spec((vocab, lbl_d)),          # qt
                spec((vocab,)),                # b
                spec((ctx, lbl_d)),            # c
                spec((lbl_b, ctx), i32),       # ctx ids
                spec((lbl_b,), i32),           # tgt
                spec((lbl_b, kn), i32),        # noise
                spec((lbl_b,)),                # ln_pn_tgt
                spec((lbl_b, kn)),             # ln_pn_noise
                spec((), jnp.float32),         # lr
            ],
        ),
    }

    meta = {"config": cfg, "graphs": {}}
    for name, (fn, args) in graphs.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--chunk", type=int, default=8192)
    ap.add_argument("--d", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=10_000)
    ap.add_argument("--lbl-d", type=int, default=100)
    ap.add_argument("--ctx", type=int, default=5)
    ap.add_argument("--noise-k", type=int, default=25)
    ap.add_argument("--lbl-batch", type=int, default=256)
    ap.add_argument("--fm-j", type=int, default=256)
    ap.add_argument("--fm-m", type=int, default=2)
    args = ap.parse_args()
    cfg = {
        "chunk": args.chunk,
        "d": args.d,
        "batch": args.batch,
        "vocab": args.vocab,
        "lbl_d": args.lbl_d,
        "ctx": args.ctx,
        "noise_k": args.noise_k,
        "lbl_batch": args.lbl_batch,
        "fm_j": args.fm_j,
        "fm_m": args.fm_m,
    }
    meta = export(args.out, cfg)
    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
