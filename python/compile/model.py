"""L2: the JAX compute graphs that get AOT-lowered to HLO text and
executed by the rust runtime. Python never runs at request time — these
functions exist to be `jax.jit(...).lower()`-ed by aot.py.

Graphs:

* ``score_chunk``      — exp scores of one category chunk vs one query
                         (Pallas ``exp_dot`` kernel inside).
* ``partition_chunk``  — partial partition sum of one chunk (Pallas).
* ``score_batch``      — partial partition sums for a query batch
                         (Pallas fused matmul+exp+reduce, grid-accumulated).
* ``fmbe_query``       — Kar-Karnick degree-m feature products for a
                         query batch (Pallas ``degree_prod``).
* ``lbl_qhat``         — LBL context projection: gather + Pallas
                         ``lbl_context`` kernel (serving path).
* ``lbl_nce_step``     — one NCE/SGD training step of the log-bilinear LM
                         with the partition clamped to 1 (Mnih & Teh
                         2012), as the paper's §5.2 trains. Uses the jnp
                         oracles (ref.py) because it differentiates
                         through the scoring ops.
"""

import jax
import jax.numpy as jnp

from .kernels import exp_dot as k_exp_dot
from .kernels import feature_map as k_fm
from .kernels import lbl as k_lbl
from .kernels import ref


# --------------------------------------------------------------------------
# Scoring graphs (serving hot path)
# --------------------------------------------------------------------------

def score_chunk(v, q):
    """exp(V q) over one chunk. v: (chunk, d), q: (d,) -> (chunk,)."""
    return (k_exp_dot.exp_dot(v, q),)


def partition_chunk(v, q):
    """Partial partition sum. v: (chunk, d), q: (d,) -> ((),)."""
    return (k_exp_dot.partition_chunk(v, q),)


def score_batch(v, qs):
    """Batch partial sums. v: (chunk, d), qs: (b, d) -> ((b,),)."""
    return (k_exp_dot.score_batch(v, qs),)


def fmbe_query(x, w):
    """FMBE degree products. x: (b, d), w: (j, m, d) -> ((b, j),)."""
    return (k_fm.degree_prod(x, w),)


# --------------------------------------------------------------------------
# Log-bilinear language model (paper §5.2)
# --------------------------------------------------------------------------

def lbl_qhat(r, c, ctx_ids):
    """Context projection for a batch of contexts.

    r: (vocab, d) context embedding table, c: (ctx, d) diagonal position
    weights, ctx_ids: (b, ctx) int32 -> ((b, d),).
    """
    r_ctx = jnp.take(r, ctx_ids, axis=0)  # (b, ctx, d)
    return (k_lbl.lbl_context(r_ctx, c),)


def lbl_nce_loss(params, batch):
    """NCE loss with Z clamped to 1 (self-normalization heuristic).

    params: dict(r (V,d), qt (V,d), b (V,), c (ctx,d))
    batch:  dict(ctx (B,ctx) i32, tgt (B,) i32, noise (B,K) i32,
                 ln_pn_tgt (B,), ln_pn_noise (B,K))

    P(data | w) = sigma(s(w) - ln(K * Pn(w))) with s(w) = qhat.qt_w + b_w
    and the model's partition taken to be 1 (never computed).
    """
    r, qt, b, c = params["r"], params["qt"], params["b"], params["c"]
    ctx, tgt, noise = batch["ctx"], batch["tgt"], batch["noise"]
    kn = noise.shape[1]
    r_ctx = jnp.take(r, ctx, axis=0)  # (B, ctx, d)
    qhat = ref.lbl_context(r_ctx, c)  # (B, d)

    tgt_emb = jnp.take(qt, tgt, axis=0)  # (B, d)
    tgt_bias = jnp.take(b, tgt, axis=0)  # (B,)
    s_tgt = jnp.sum(qhat * tgt_emb, axis=1) + tgt_bias

    noise_emb = jnp.take(qt, noise, axis=0)  # (B, K, d)
    noise_bias = jnp.take(b, noise, axis=0)  # (B, K)
    s_noise = ref.lbl_scores(qhat, noise_emb, noise_bias)

    ln_k = jnp.log(jnp.float32(kn))
    delta_tgt = s_tgt - (ln_k + batch["ln_pn_tgt"])
    delta_noise = s_noise - (ln_k + batch["ln_pn_noise"])
    loss = -(
        jnp.mean(jax.nn.log_sigmoid(delta_tgt))
        + jnp.mean(jnp.sum(jax.nn.log_sigmoid(-delta_noise), axis=1))
    )
    return loss


def lbl_nce_step(r, qt, b, c, ctx, tgt, noise, ln_pn_tgt, ln_pn_noise, lr):
    """One SGD step; returns (r', qt', b', c', loss)."""
    params = {"r": r, "qt": qt, "b": b, "c": c}
    batch = {
        "ctx": ctx,
        "tgt": tgt,
        "noise": noise,
        "ln_pn_tgt": ln_pn_tgt,
        "ln_pn_noise": ln_pn_noise,
    }
    loss, grads = jax.value_and_grad(lbl_nce_loss)(params, batch)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return (new["r"], new["qt"], new["b"], new["c"], loss)
