"""AOT artifact tests: every graph lowers to parseable HLO text whose
entry computation has the argument count meta.json declares, and the
lowered scoring graphs produce the same numbers as direct execution."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

SMALL_CFG = {
    "chunk": 256,
    "d": 16,
    "batch": 4,
    "vocab": 60,
    "lbl_d": 8,
    "ctx": 3,
    "noise_k": 5,
    "lbl_batch": 8,
    "fm_j": 16,
    "fm_m": 2,
}


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = aot.export(str(out), dict(SMALL_CFG))
    return out, meta


def test_all_graphs_written(exported):
    out, meta = exported
    for name, info in meta["graphs"].items():
        path = os.path.join(out, info["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text


def test_meta_declares_argument_shapes(exported):
    _, meta = exported
    g = meta["graphs"]["score_chunk"]
    assert g["args"][0]["shape"] == [256, 16]
    assert g["args"][1]["shape"] == [16]
    g = meta["graphs"]["lbl_nce_step"]
    assert len(g["args"]) == 10
    assert g["args"][4]["dtype"] == "int32"


def test_hlo_parameter_count_matches_meta(exported):
    out, meta = exported
    for name, info in meta["graphs"].items():
        text = open(os.path.join(out, info["file"])).read()
        # Count parameter instructions in the ENTRY computation.
        entry = text[text.index("ENTRY") :]
        body = entry[: entry.index("\n}")]
        n_params = body.count(" = f32[") + body.count(" = s32[")
        n_params = sum(
            1 for line in body.splitlines() if "parameter(" in line
        )
        assert n_params == len(info["args"]), name


def test_lowered_partition_matches_direct():
    # Execute the lowered (compiled) graph and the python function on the
    # same inputs — the artifact calculation must be identical.
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(256, 16)) * 0.3, jnp.float32)
    q = jnp.asarray(rng.normal(size=(16,)) * 0.3, jnp.float32)
    lowered = jax.jit(model.partition_chunk).lower(
        jax.ShapeDtypeStruct((256, 16), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.float32),
    )
    compiled = lowered.compile()
    (got,) = compiled(v, q)
    (want,) = model.partition_chunk(v, q)
    assert float(got) == pytest.approx(float(want), rel=1e-6)


def test_meta_json_roundtrip(exported):
    out, meta = exported
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(meta, f)
        path = f.name
    back = json.load(open(path))
    assert back["config"]["chunk"] == SMALL_CFG["chunk"]
    os.unlink(path)
