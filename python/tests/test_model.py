"""L2 graph tests: shapes, gradients, and training behaviour of the
log-bilinear NCE step, plus the scoring graphs' numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def make_params(rng, vocab=50, d=8, ctx=3):
    return {
        "r": jnp.asarray(rng.normal(size=(vocab, d)) * 0.1, jnp.float32),
        "qt": jnp.asarray(rng.normal(size=(vocab, d)) * 0.1, jnp.float32),
        "b": jnp.zeros((vocab,), jnp.float32),
        "c": jnp.asarray(rng.normal(size=(ctx, d)) * 0.1, jnp.float32),
    }


def make_batch(rng, vocab=50, bsz=16, ctx=3, kn=5):
    noise = rng.integers(0, vocab, size=(bsz, kn))
    return {
        "ctx": jnp.asarray(rng.integers(0, vocab, size=(bsz, ctx)), jnp.int32),
        "tgt": jnp.asarray(rng.integers(0, vocab, size=(bsz,)), jnp.int32),
        "noise": jnp.asarray(noise, jnp.int32),
        "ln_pn_tgt": jnp.full((bsz,), -np.log(vocab), jnp.float32),
        "ln_pn_noise": jnp.full((bsz, kn), -np.log(vocab), jnp.float32),
    }


def test_lbl_qhat_matches_manual_gather():
    rng = np.random.default_rng(0)
    p = make_params(rng)
    ctx_ids = jnp.asarray(rng.integers(0, 50, size=(7, 3)), jnp.int32)
    (qhat,) = model.lbl_qhat(p["r"], p["c"], ctx_ids)
    manual = ref.lbl_context(jnp.take(p["r"], ctx_ids, axis=0), p["c"])
    assert_allclose(np.asarray(qhat), np.asarray(manual), rtol=1e-5, atol=1e-6)


def test_nce_loss_finite_and_positive():
    rng = np.random.default_rng(1)
    loss = model.lbl_nce_loss(make_params(rng), make_batch(rng))
    assert np.isfinite(float(loss))
    assert float(loss) > 0.0


def test_nce_step_decreases_loss_on_fixed_batch():
    rng = np.random.default_rng(2)
    p = make_params(rng)
    batch = make_batch(rng)
    args = (
        p["r"], p["qt"], p["b"], p["c"],
        batch["ctx"], batch["tgt"], batch["noise"],
        batch["ln_pn_tgt"], batch["ln_pn_noise"],
    )
    step = jax.jit(model.lbl_nce_step)
    lr = jnp.float32(0.5)
    r, qt, b, c, loss0 = step(*args, lr)
    for _ in range(20):
        r, qt, b, c, loss = step(
            r, qt, b, c,
            batch["ctx"], batch["tgt"], batch["noise"],
            batch["ln_pn_tgt"], batch["ln_pn_noise"], lr,
        )
    assert float(loss) < float(loss0), (float(loss0), float(loss))


def test_nce_step_shapes_preserved():
    rng = np.random.default_rng(3)
    p = make_params(rng)
    batch = make_batch(rng)
    out = model.lbl_nce_step(
        p["r"], p["qt"], p["b"], p["c"],
        batch["ctx"], batch["tgt"], batch["noise"],
        batch["ln_pn_tgt"], batch["ln_pn_noise"], jnp.float32(0.1),
    )
    r, qt, b, c, loss = out
    assert r.shape == p["r"].shape
    assert qt.shape == p["qt"].shape
    assert b.shape == p["b"].shape
    assert c.shape == p["c"].shape
    assert loss.shape == ()


def test_nce_gradients_nonzero_only_for_touched_rows():
    rng = np.random.default_rng(4)
    p = make_params(rng, vocab=30)
    batch = make_batch(rng, vocab=30, bsz=2, kn=2)
    grads = jax.grad(model.lbl_nce_loss)(p, batch)
    touched = set(np.asarray(batch["tgt"]).tolist())
    touched |= set(np.asarray(batch["noise"]).ravel().tolist())
    g = np.asarray(grads["qt"])
    for w in range(30):
        row_norm = np.abs(g[w]).sum()
        if w in touched:
            continue  # may or may not be large; target rows usually are
        assert row_norm == pytest.approx(0.0, abs=1e-12), f"untouched row {w} has grad"


def test_score_graphs_consistent_with_each_other():
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.normal(size=(600, 12)) * 0.3, jnp.float32)
    q = jnp.asarray(rng.normal(size=(12,)) * 0.3, jnp.float32)
    (scores,) = model.score_chunk(v, q)
    (z,) = model.partition_chunk(v, q)
    assert_allclose(float(jnp.sum(scores)), float(z), rtol=1e-5)
    (zb,) = model.score_batch(v, q[None, :])
    assert_allclose(float(zb[0]), float(z), rtol=1e-5)


def test_fmbe_query_graph():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 10)) * 0.4, jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], size=(8, 2, 10)), jnp.float32)
    (out,) = model.fmbe_query(x, w)
    assert_allclose(np.asarray(out), np.asarray(ref.degree_prod(x, w)), rtol=1e-4)
