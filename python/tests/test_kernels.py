"""Kernel-vs-oracle correctness: hypothesis sweeps shapes (and scales)
of every Pallas kernel against the pure-jnp reference in ref.py.
This is the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import exp_dot as k_exp
from compile.kernels import feature_map as k_fm
from compile.kernels import lbl as k_lbl
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rng_for(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- exp_dot

@settings(**SETTINGS)
@given(
    n=st.integers(1, 3000),
    d=st.sampled_from([1, 7, 32, 300]),
    seed=st.integers(0, 2**32 - 1),
)
def test_exp_dot_matches_ref(n, d, seed):
    r = rng_for(seed)
    v = (r.normal(size=(n, d)) * 0.3).astype(np.float32)
    q = (r.normal(size=(d,)) * 0.3).astype(np.float32)
    assert_allclose(k_exp.exp_dot(v, q), ref.exp_dot(v, q), rtol=2e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 5000),
    block=st.sampled_from([32, 256, 1024]),
    seed=st.integers(0, 2**32 - 1),
)
def test_partition_chunk_matches_ref(n, block, seed):
    r = rng_for(seed)
    v = (r.normal(size=(n, 16)) * 0.4).astype(np.float32)
    q = (r.normal(size=(16,)) * 0.4).astype(np.float32)
    got = float(k_exp.partition_chunk(v, q, block_n=block))
    want = float(ref.partition_chunk(v, q))
    assert_allclose(got, want, rtol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3000),
    b=st.integers(1, 24),
    seed=st.integers(0, 2**32 - 1),
)
def test_score_batch_matches_ref(n, b, seed):
    r = rng_for(seed)
    v = (r.normal(size=(n, 24)) * 0.3).astype(np.float32)
    qs = (r.normal(size=(b, 24)) * 0.3).astype(np.float32)
    assert_allclose(k_exp.score_batch(v, qs), ref.score_batch(v, qs), rtol=1e-4)


def test_exp_dot_padding_boundary():
    # n exactly one below/above a block multiple.
    r = rng_for(7)
    for n in [1023, 1024, 1025]:
        v = (r.normal(size=(n, 8)) * 0.2).astype(np.float32)
        q = (r.normal(size=(8,)) * 0.2).astype(np.float32)
        assert_allclose(k_exp.exp_dot(v, q), ref.exp_dot(v, q), rtol=2e-5)
        assert_allclose(
            float(k_exp.partition_chunk(v, q)),
            float(ref.partition_chunk(v, q)),
            rtol=1e-4,
        )


def test_partition_padding_correction_vs_large_scores():
    # Padded rows contribute exp(0)=1 each; the correction must remove
    # exactly that even when true scores are large.
    r = rng_for(11)
    v = (r.normal(size=(1000, 8)) * 1.5).astype(np.float32)
    q = (r.normal(size=(8,)) * 1.5).astype(np.float32)
    got = float(k_exp.partition_chunk(v, q, block_n=512))
    want = float(ref.partition_chunk(v, q))
    assert_allclose(got, want, rtol=1e-4)


# ----------------------------------------------------------- feature_map

@settings(**SETTINGS)
@given(
    b=st.integers(1, 200),
    j=st.integers(1, 64),
    m=st.integers(0, 4),
    seed=st.integers(0, 2**32 - 1),
)
def test_degree_prod_matches_ref(b, j, m, seed):
    r = rng_for(seed)
    x = (r.normal(size=(b, 12)) * 0.5).astype(np.float32)
    w = r.choice([-1.0, 1.0], size=(j, m, 12)).astype(np.float32)
    assert_allclose(
        k_fm.degree_prod(x, w), ref.degree_prod(x, w), rtol=1e-4, atol=1e-6
    )


def test_degree_prod_zero_degree_is_ones():
    x = np.zeros((5, 4), np.float32)
    w = np.zeros((9, 0, 4), np.float32)
    out = np.asarray(k_fm.degree_prod(x, w))
    assert out.shape == (5, 9)
    assert (out == 1.0).all()


# ------------------------------------------------------------------- lbl

@settings(**SETTINGS)
@given(
    b=st.integers(1, 300),
    ctx=st.integers(1, 9),
    d=st.sampled_from([4, 32, 100]),
    seed=st.integers(0, 2**32 - 1),
)
def test_lbl_context_matches_ref(b, ctx, d, seed):
    r = rng_for(seed)
    r_ctx = r.normal(size=(b, ctx, d)).astype(np.float32)
    c = r.normal(size=(ctx, d)).astype(np.float32)
    assert_allclose(
        k_lbl.lbl_context(r_ctx, c), ref.lbl_context(r_ctx, c), rtol=1e-4, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    b=st.integers(1, 200),
    k=st.integers(1, 30),
    seed=st.integers(0, 2**32 - 1),
)
def test_lbl_scores_matches_ref(b, k, seed):
    r = rng_for(seed)
    q = r.normal(size=(b, 16)).astype(np.float32)
    e = r.normal(size=(b, k, 16)).astype(np.float32)
    bias = r.normal(size=(b, k)).astype(np.float32)
    assert_allclose(
        k_lbl.lbl_scores(q, e, bias), ref.lbl_scores(q, e, bias), rtol=1e-4, atol=1e-5
    )


# --------------------------------------------------- numerical edge cases

@pytest.mark.parametrize("scale", [0.0, 1e-6, 3.0])
def test_exp_dot_extreme_scales(scale):
    r = rng_for(3)
    v = (r.normal(size=(100, 8)) * scale).astype(np.float32)
    q = (r.normal(size=(8,)) * scale).astype(np.float32)
    assert_allclose(k_exp.exp_dot(v, q), ref.exp_dot(v, q), rtol=1e-4)


def test_zero_query_gives_n():
    v = rng_for(4).normal(size=(123, 8)).astype(np.float32)
    q = np.zeros((8,), np.float32)
    # The paper's pathological case |q| = 0: Z = N exactly.
    assert float(k_exp.partition_chunk(v, q)) == pytest.approx(123.0, rel=1e-6)
