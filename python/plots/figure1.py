"""Render Figure 1 (CDF over sorted contributions) from the bench JSON.

Usage:  python python/plots/figure1.py [results/figure1_paper.json] [out.png]

Build-time tooling only — never on the request path.
"""

import json
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt


def main() -> None:
    src = sys.argv[1] if len(sys.argv) > 1 else "results/figure1_paper.json"
    out = sys.argv[2] if len(sys.argv) > 2 else "results/figure1.png"
    curves = json.load(open(src))
    fig, ax = plt.subplots(figsize=(7, 4.5))
    for c in curves:
        xs = [p[0] for p in c["series"]]
        ys = [p[1] for p in c["series"]]
        ax.plot(xs, ys, label=f"rank {c['rank']} ({c['corpus_freq']:,})")
    ax.axhline(0.8, color="gray", ls=":", lw=0.8)
    ax.set_xlabel("fraction of vocabulary (sorted by contribution)")
    ax.set_ylabel("fraction of Z covered")
    ax.set_title("CDF of sorted contributions to Z (synthetic word2vec-like)")
    ax.legend(fontsize=7, title="probe token (pseudo freq)")
    ax.set_xscale("log")
    fig.tight_layout()
    fig.savefig(out, dpi=150)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
